//! Figure 7: a burst of 96 workers loading the same object from S3 at
//! different granularities. Burst packs download once per pack with
//! pack-parallel byte-range reads and share zero-copy; FaaS (g = 1)
//! downloads one full copy per worker. Paper: 32.6× faster at g = 48
//! for a 1 GiB object.


use crate::bcm::{BackendKind, BurstContext, CommFabric, FabricConfig, PackTopology};
use crate::cluster::netmodel::NetParams;
use crate::storage::ObjectStore;
use crate::util::benchkit::{section, Table};
use crate::util::bytes::{self, MIB};
use crate::util::timing::Stopwatch;

#[derive(Debug, Clone)]
pub struct Row {
    pub granularity: usize,
    /// Time until every worker holds the data (seconds, modeled).
    pub load_s: f64,
    pub speedup_vs_g1: f64,
    pub storage_bytes_read: u64,
}

pub struct Config {
    pub workers: usize,
    pub object_bytes: usize,
    pub time_scale: f64,
    pub grans: Vec<usize>,
}

impl Config {
    pub fn new(quick: bool) -> Config {
        if quick {
            Config {
                workers: 24,
                object_bytes: 8 * MIB,
                time_scale: 0.5,
                grans: vec![1, 4, 12, 24],
            }
        } else {
            Config {
                workers: 96,
                object_bytes: 8 * MIB,
                time_scale: 1.0,
                grans: vec![1, 2, 4, 8, 16, 32, 48, 96],
            }
        }
    }
}

pub fn compute(cfg: &Config) -> Vec<Row> {
    let params = NetParams::scaled(cfg.time_scale);
    let mut rows = Vec::new();
    let mut g1 = None;
    for &g in &cfg.grans {
        // Fresh store per run so stats are per-granularity.
        let store = ObjectStore::new(params.clone());
        store.preload("fig7/obj", vec![0u8; cfg.object_bytes]);
        let fabric = CommFabric::new(
            "fig7",
            PackTopology::contiguous(cfg.workers, g),
            BackendKind::DragonflyList.build(&params),
            &params,
            FabricConfig::default(),
        );
        let sw = Stopwatch::start();
        std::thread::scope(|s| {
            for w in 0..cfg.workers {
                let fabric = fabric.clone();
                let store = store.clone();
                s.spawn(move || {
                    let ctx = BurstContext::new(w, fabric);
                    let data = if ctx.is_leader() {
                        let conns = ctx.pack_members().len();
                        let d = store.get_parallel("fig7/obj", conns).unwrap();
                        ctx.pack_share(Some(d)).unwrap()
                    } else {
                        ctx.pack_share(None).unwrap()
                    };
                    assert_eq!(data.len(), store.size("fig7/obj").unwrap());
                });
            }
        });
        let load_s = sw.secs() / cfg.time_scale; // report modeled seconds
        let first = *g1.get_or_insert(load_s);
        rows.push(Row {
            granularity: g,
            load_s,
            speedup_vs_g1: first / load_s,
            storage_bytes_read: store.stats.bytes_read.load(std::sync::atomic::Ordering::Relaxed),
        });
    }
    rows
}

pub fn run(quick: bool) -> Vec<Row> {
    let cfg = Config::new(quick);
    section(&format!(
        "Figure 7: {} workers loading a {} object from S3",
        cfg.workers,
        bytes::human(cfg.object_bytes as u64)
    ));
    let rows = compute(&cfg);
    let mut t = Table::new(&["Granularity", "Load time", "Speed-up vs FaaS", "Bytes from S3"]);
    for r in &rows {
        t.row(vec![
            r.granularity.to_string(),
            format!("{:.3}s", r.load_s),
            format!("{:.1}x", r.speedup_vs_g1),
            bytes::human(r.storage_bytes_read),
        ]);
    }
    t.print();
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packing_accelerates_loading_and_cuts_ingestion() {
        let _guard = crate::util::timing::timing_test_lock();
        let rows = compute(&Config::new(true));
        // Monotone speed-up with granularity (generous tolerance: the test
        // suite runs in parallel on one CPU).
        for w in rows.windows(2) {
            assert!(
                w[1].load_s < w[0].load_s * 1.3,
                "g{} {} vs g{} {}",
                w[1].granularity,
                w[1].load_s,
                w[0].granularity,
                w[0].load_s
            );
        }
        let last = rows.last().unwrap();
        assert!(last.speedup_vs_g1 > 3.0, "speed-up {}", last.speedup_vs_g1);
        // Ingestion: FaaS reads workers× the object; one pack reads ~1×.
        assert!(rows[0].storage_bytes_read > 20 * last.storage_bytes_read / 2);
    }
}
