//! The burst controller (paper Fig. 4): handles deploy and flare requests,
//! oversees invoker resources, performs worker packing, and stores results.
//!
//! Flares flow through the scheduling pipeline in [`super::queue`]:
//! `submit_flare` admits (validates against the largest registered node's
//! capacity) and queues without blocking; the scheduler thread places and
//! runs each flare on its own execution thread; `flare` is a thin
//! submit-and-wait wrapper.
//!
//! Placement is **two-level** (see [`super::node`]): the cluster-side
//! [`NodeRegistry`] scores candidate nodes per flare and each node's agent
//! makes the local admission decision — a refusal (stale view, concurrency
//! cap) spills the flare back for re-planning under a bounded budget, and
//! the explainable decision (winner score, per-candidate reject reasons)
//! is persisted on the flare record.
//!
//! Every flare belongs to a *tenant* lane with a *priority* class
//! ([`FlareOptions::tenant`] / [`FlareOptions::priority`]) and can be
//! killed through [`Controller::cancel_flare`]: queued flares are pulled
//! out before placement and their waiters fail fast; running flares have
//! their [`CancelToken`] tripped, which the execution path observes at
//! phase boundaries so the reservation is released promptly.
//!
//! Priorities also *reclaim*: when a `high` flare is starved, the
//! scheduler preempts running lower-priority flares
//! ([`Controller::preempt_for_starved_high_flare`]) — their tokens trip
//! with the `Preempted` reason, the workers unwind, and each victim is
//! requeued at the head of its lane with `preempt_count + 1` (capped by
//! the policy's livelock guard; opt out per flare with
//! [`FlareOptions::preemptible`]). Flares may carry a queueing deadline
//! ([`FlareOptions::deadline_ms`]): earliest-deadline-first breaks ties
//! within a priority class, and a flare still queued past its deadline
//! fails fast with [`FlareStatus::Expired`].

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::db::{self, BurstConfig, BurstDb, BurstDefinition, FlareRecord, FlareStatus};
use super::invoker::{model_startup, InvokerPool, ModeledStartup};
use super::node::{NodePlacement, NodeRegistry, DEFAULT_NODE};
use super::pack::run_flare_packs;
use super::packing::{PackSpec, PackingStrategy};
use super::queue::{
    scheduler_loop, select_victims, FlareHandle, PreemptCandidate, Priority,
    QueuedFlare, ResultSlot, SchedState, TenantPolicy, DEFAULT_TENANT,
    MAX_BACKFILL_PASSES,
};
use super::store::{DurableStore, FsyncPolicy};
use crate::bcm::{
    BackendKind, Bytes, CheckpointChannel, CommFabric, FabricConfig, PackTopology,
    RemoteBackend,
};
use crate::cluster::costmodel::CostModel;
use crate::cluster::netmodel::NetParams;
use crate::cluster::ClusterSpec;
use crate::metrics::{Timeline, TrafficStats};
use crate::util::cancel::{CancelReason, CancelToken};
use crate::util::json::Json;
use crate::util::rng::Pcg;
use crate::util::sync::{LockRank, RankedMutex};

/// Default cap on how many times one flare may be preempted and requeued
/// (the livelock guard: at the cap it stops being selectable as a victim).
pub const DEFAULT_MAX_PREEMPTS: u32 = 3;

/// What [`Controller::recover`] found and did while replaying the durable
/// store (surfaced in `/metrics`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RecoveryStats {
    /// Terminal flare records restored as history, byte-for-byte.
    pub terminal_restored: u64,
    /// Flares that were `queued`/`running` at crash time, re-admitted at
    /// the head of their tenant lane in original submit order.
    pub requeued: u64,
    /// Non-terminal flares whose work function (or definition) is no
    /// longer available: marked `Failed` with a "lost at restart" error.
    pub lost_work: u64,
    /// Tenant lanes whose weight/quota policy was reinstated.
    pub tenants_restored: u64,
    /// Worker checkpoints re-seeded for re-admitted flares, so their
    /// re-run resumes from saved progress instead of from scratch.
    pub checkpoints_restored: u64,
    /// Burst definitions redeployed.
    pub defs_restored: u64,
    /// Definitions left dormant because their work fn is unregistered in
    /// this build (they return if a later build registers it again).
    pub defs_unregistered: u64,
    /// Corrupt / truncated / unreadable WAL lines and records skipped.
    pub skipped: u64,
}

impl RecoveryStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("terminal_restored", self.terminal_restored.into()),
            ("requeued", self.requeued.into()),
            ("lost_work", self.lost_work.into()),
            ("tenants_restored", self.tenants_restored.into()),
            ("checkpoints_restored", self.checkpoints_restored.into()),
            ("defs_restored", self.defs_restored.into()),
            ("defs_unregistered", self.defs_unregistered.into()),
            ("skipped", self.skipped.into()),
        ])
    }
}

/// Per-flare execution options (overrides of the deployed config).
#[derive(Debug, Clone, Default)]
pub struct FlareOptions {
    /// Override granularity.
    pub granularity: Option<usize>,
    /// Override packing strategy.
    pub strategy: Option<String>,
    /// Override backend.
    pub backend: Option<BackendKind>,
    /// Run as a FaaS baseline: forces granularity 1 and independent
    /// per-worker invocations (arrival skew + per-container code load).
    pub faas: bool,
    /// Fair-share tenant lane (defaults to [`DEFAULT_TENANT`]).
    pub tenant: Option<String>,
    /// Priority class name within the tenant: `low` | `normal` | `high`
    /// (validated at submit; defaults to `normal`).
    pub priority: Option<String>,
    /// May the scheduler preempt this flare to reclaim capacity for a
    /// `high` one? Defaults to `true`; set `false` to opt out.
    pub preemptible: Option<bool>,
    /// Queueing deadline in milliseconds from submission: EDF tie-break
    /// within a priority class, and a flare still queued past it fails
    /// fast with `FlareStatus::Expired`.
    pub deadline_ms: Option<u64>,
    /// DAG edges: ids of already-submitted flares this one depends on.
    /// The flare waits outside the DRR lanes (`waiting_on_parents`) until
    /// every parent reaches `Completed`, then enters the lanes with the
    /// parents' outputs staged into its backend
    /// ([`crate::bcm::BurstContext::parent_input`]) and placement biased
    /// toward the parents' nodes. A parent that ends any other way fails
    /// this flare fast with [`FlareStatus::ParentFailed`].
    pub after: Vec<String>,
}

impl FlareOptions {
    pub fn from_json(j: &Json) -> FlareOptions {
        FlareOptions {
            granularity: j.get("granularity").and_then(Json::as_usize),
            strategy: j.get("strategy").and_then(Json::as_str).map(str::to_string),
            backend: j.get("backend").and_then(Json::as_str).and_then(BackendKind::parse),
            faas: j.get("faas").and_then(Json::as_bool).unwrap_or(false),
            tenant: j.get("tenant").and_then(Json::as_str).map(str::to_string),
            priority: j.get("priority").and_then(Json::as_str).map(str::to_string),
            preemptible: j.get("preemptible").and_then(Json::as_bool),
            deadline_ms: j.get("deadline_ms").and_then(Json::as_usize).map(|d| d as u64),
            after: j
                .get("after")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter().filter_map(Json::as_str).map(str::to_string).collect()
                })
                .unwrap_or_default(),
        }
    }
}

/// What `Controller::cancel_flare` did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelOutcome {
    /// The flare was still queued: removed before placement, waiter failed
    /// fast, terminal `Cancelled` status recorded.
    CancelledQueued,
    /// The flare was running: its token is tripped and the workers stop at
    /// the next cancellation point, releasing the reservation.
    CancellingRunning,
}

impl CancelOutcome {
    pub fn name(&self) -> &'static str {
        match self {
            CancelOutcome::CancelledQueued => "cancelled",
            CancelOutcome::CancellingRunning => "cancelling",
        }
    }
}

/// Why a cancel request was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelError {
    /// No flare with this id exists (never submitted, or evicted).
    NotFound,
    /// The flare already reached a terminal state — nothing left to kill.
    AlreadyTerminal(FlareStatus),
}

impl std::fmt::Display for CancelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CancelError::NotFound => write!(f, "flare not found"),
            CancelError::AlreadyTerminal(s) => {
                write!(f, "flare already {} — nothing to cancel", s.name())
            }
        }
    }
}

impl std::error::Error for CancelError {}

/// Result of one flare.
pub struct FlareResult {
    pub flare_id: String,
    pub outputs: Vec<Json>,
    pub packs: Vec<PackSpec>,
    pub startup: ModeledStartup,
    pub timeline: Arc<Timeline>,
    pub traffic: Arc<TrafficStats>,
    pub backend_name: String,
    /// Measured work wall-time (max across workers), seconds.
    pub work_wall_s: f64,
    /// Measured wall-time between submission and placement, seconds
    /// (near-zero on an idle cluster; the queueing delay under load).
    pub queue_wait_s: f64,
}

impl FlareResult {
    /// End-to-end modeled job time: invocation latency + measured work.
    pub fn total_s(&self) -> f64 {
        self.startup.all_ready_s + self.work_wall_s
    }

    pub fn summary_json(&self) -> Json {
        Json::obj(vec![
            ("flare_id", self.flare_id.as_str().into()),
            ("packs", self.packs.len().into()),
            ("burst_size", self.startup.worker_ready_s.len().into()),
            ("backend", self.backend_name.as_str().into()),
            ("invocation_s", self.startup.all_ready_s.into()),
            ("work_s", self.work_wall_s.into()),
            ("total_s", self.total_s().into()),
            ("remote_bytes", (self.traffic.remote() as usize).into()),
            ("local_bytes", (self.traffic.local() as usize).into()),
            ("queue_wait_s", self.queue_wait_s.into()),
        ])
    }
}

/// A placed flare the preemption policy can see (and select from).
struct RunningFlare {
    priority: Priority,
    /// vCPUs its reservation holds (= burst size).
    vcpus: usize,
    /// Placement sequence; higher = started more recently.
    seq: u64,
    preemptible: bool,
    preempt_count: u32,
    cancel: CancelToken,
    /// Already tripped for preemption: its vCPUs count as in-flight
    /// reclaim, so successive scheduler passes don't over-preempt.
    preempting: bool,
    /// Node hosting the reservation (victim selection is node-aware, and
    /// a node death fails over exactly the flares it was hosting).
    node: String,
}

/// The burst platform controller.
pub struct Controller {
    pub db: BurstDb,
    /// The first registered node's pool (the whole cluster in the
    /// single-node constructors; a convenience handle in multi-node ones).
    pub pool: Arc<InvokerPool>,
    /// Cluster control plane: registered nodes, liveness, resource views,
    /// and the placement engine over them.
    pub nodes: Arc<NodeRegistry>,
    pub cost: CostModel,
    pub net: NetParams,
    /// Backends are created per kind on first use and shared across flares
    /// (they are the remote *servers*).
    backends: RankedMutex<Vec<(BackendKind, Arc<dyn RemoteBackend>)>>,
    rng: RankedMutex<Pcg>,
    next_flare: AtomicU64,
    /// Shared with the scheduler thread and flare execution threads.
    sched: Arc<SchedState>,
    sched_thread: RankedMutex<Option<JoinHandle<()>>>,
    /// Cancel tokens of every non-terminal flare, by id (the kill path).
    /// Rank `Cancels`: token trips under it cascade into waker locks.
    cancels: RankedMutex<HashMap<String, CancelToken>>,
    /// Currently placed flares, by id: the preemption policy's view.
    /// Rank `Running`: held across node-status reads and token trips.
    running: RankedMutex<HashMap<String, RunningFlare>>,
    /// Placement sequence counter (recency order for victim selection).
    next_seq: AtomicU64,
    /// Preemption policy knobs (see [`Controller::set_preemption_policy`]).
    preempt_enabled: AtomicBool,
    max_preempts: AtomicU32,
    /// Lifetime counters surfaced in `/metrics`.
    preempted_total: AtomicU64,
    expired_total: AtomicU64,
    /// Runs that started with prior checkpoints to restore (resumes).
    resumed_total: AtomicU64,
    /// Durable sink for tenant-policy appends (`BurstDb` holds its own
    /// reference for deploy/flare appends). `None` = in-memory only.
    store: Option<Arc<DurableStore>>,
    /// What `Controller::recover` replayed (zeroes for a fresh start).
    recovery: RankedMutex<RecoveryStats>,
    /// Flare id → wait reason currently written on its db record
    /// (`quota_blocked` / `no_feasible_node`), so `sync_wait_reasons`
    /// only writes — and WALs — on transitions. Held across the db
    /// writes, hence its rank below `FlareShard`.
    wait_marked: RankedMutex<HashMap<String, &'static str>>,
}

impl Controller {
    pub fn new(cluster: ClusterSpec, cost: CostModel, net: NetParams) -> Arc<Controller> {
        Controller::new_multi(vec![(DEFAULT_NODE.to_string(), cluster)], cost, net)
    }

    /// Build a controller over several invoker nodes, each owning its own
    /// pool behind a node agent. A flare never spans nodes (the fabric is
    /// node-local), so admission bounds against the *largest* node.
    pub fn new_multi(
        nodes: Vec<(String, ClusterSpec)>,
        cost: CostModel,
        net: NetParams,
    ) -> Arc<Controller> {
        Controller::new_inner(&nodes, cost, net, None, false)
    }

    fn new_inner(
        node_specs: &[(String, ClusterSpec)],
        cost: CostModel,
        net: NetParams,
        store: Option<Arc<DurableStore>>,
        paused: bool,
    ) -> Arc<Controller> {
        assert!(!node_specs.is_empty(), "a controller needs at least one node");
        let nodes = Arc::new(NodeRegistry::new());
        let mut first_pool = None;
        for (name, cluster) in node_specs {
            let pool = Arc::new(InvokerPool::new(cluster));
            nodes.register(name, pool.clone());
            if first_pool.is_none() {
                first_pool = Some(pool);
            }
        }
        let pool = first_pool.expect("at least one node");
        Arc::new_cyclic(|weak| {
            let sched = SchedState::new(MAX_BACKFILL_PASSES);
            if paused {
                // Recovery replay window: the scheduler thread runs but
                // places nothing until `SchedState::resume`.
                sched.pause();
            }
            let handle = {
                let sched = sched.clone();
                let weak = weak.clone();
                std::thread::Builder::new()
                    .name("flare-scheduler".into())
                    .spawn(move || scheduler_loop(sched, weak))
                    .expect("spawn flare scheduler")
            };
            let db = BurstDb::new();
            if let Some(s) = &store {
                db.attach_store(s.clone());
            }
            Controller {
                db,
                pool,
                nodes,
                cost,
                net,
                backends: RankedMutex::new(LockRank::Leaf, Vec::new()),
                rng: RankedMutex::new(LockRank::Leaf, Pcg::new(0xb5_2024)),
                next_flare: AtomicU64::new(1),
                sched,
                sched_thread: RankedMutex::new(LockRank::Leaf, Some(handle)),
                cancels: RankedMutex::new(LockRank::Cancels, HashMap::new()),
                running: RankedMutex::new(LockRank::Running, HashMap::new()),
                next_seq: AtomicU64::new(0),
                preempt_enabled: AtomicBool::new(true),
                max_preempts: AtomicU32::new(DEFAULT_MAX_PREEMPTS),
                preempted_total: AtomicU64::new(0),
                expired_total: AtomicU64::new(0),
                resumed_total: AtomicU64::new(0),
                store,
                recovery: RankedMutex::new(LockRank::Leaf, RecoveryStats::default()),
                wait_marked: RankedMutex::new(LockRank::WaitMarked, HashMap::new()),
            }
        })
    }

    /// Build a controller whose control-plane state is durable under
    /// `state_dir`, replaying whatever a previous process left there
    /// (paper Fig. 4's burst DB, made restart-proof):
    ///
    /// * **Terminal flares** are restored as history, untouched.
    /// * **Non-terminal flares** (queued or running at crash time) are
    ///   re-admitted at the head of their tenant lane in original submit
    ///   order, with their original wall-clock submit time and remaining
    ///   deadline — or marked `Failed` with a `lost at restart` error when
    ///   their definition / work function is no longer registered.
    /// * **Tenant weights and quotas** are reinstated *before* the
    ///   scheduler is allowed a placement pass (it starts paused).
    ///
    /// A fresh (empty) `state_dir` yields a normal controller that simply
    /// persists from now on, so `recover` is also the way to *enable*
    /// durability.
    pub fn recover(
        cluster: ClusterSpec,
        cost: CostModel,
        net: NetParams,
        state_dir: &Path,
    ) -> Result<Arc<Controller>> {
        Controller::recover_multi(
            vec![(DEFAULT_NODE.to_string(), cluster)],
            cost,
            net,
            state_dir,
        )
    }

    /// Multi-node [`Controller::recover`]: the `nodes` list is the set of
    /// nodes that *re-registered* after the restart. A non-terminal flare
    /// whose recorded node is not in that set is failed as lost — its
    /// state lived on a node that never came back.
    pub fn recover_multi(
        nodes: Vec<(String, ClusterSpec)>,
        cost: CostModel,
        net: NetParams,
        state_dir: &Path,
    ) -> Result<Arc<Controller>> {
        let store = Arc::new(DurableStore::open(state_dir)?);
        let loaded = store.loaded();
        let this = Controller::new_inner(&nodes, cost, net, Some(store.clone()), true);
        let mut stats =
            RecoveryStats { skipped: loaded.skipped_lines as u64, ..Default::default() };

        // Definitions first (flare re-admission resolves work through
        // them). A def whose work fn is not registered in this build is
        // left dormant in the store: it returns if a later build
        // registers the work again, and its flares fail explicitly below.
        for def in &loaded.defs {
            let name = def.str_or("name", "").to_string();
            let work_name = def.str_or("work", "").to_string();
            let conf = def.get("conf").map(BurstConfig::from_json).unwrap_or_default();
            if this.db.deploy(BurstDefinition { name, work_name, conf }).is_ok() {
                stats.defs_restored += 1;
            } else {
                stats.defs_unregistered += 1;
            }
        }

        // Tenant policy next, while the scheduler is still paused: no
        // flare may be placed under not-yet-restored weights or quotas.
        // Lifetime billing meters are re-seeded from their last settled
        // absolute totals (usage entries replay as idempotent overwrites).
        {
            let mut q = this.sched.queue.lock();
            for (tenant, weight, quota) in &loaded.tenants {
                q.set_tenant_weight(tenant, *weight);
                q.set_tenant_quota(tenant, *quota);
                stats.tenants_restored += 1;
            }
            for (tenant, total) in &loaded.usage {
                q.seed_billed(tenant, *total);
            }
        }

        // Group the persisted worker checkpoints by flare: re-admitted
        // flares get them re-seeded so their re-run *resumes* (checkpoints
        // of terminal or lost flares are dead state and simply dropped —
        // `put_flare`'s terminal transition stages the WAL drop).
        let mut ckpts_by_flare: HashMap<String, Vec<(usize, u64, Vec<u8>)>> =
            HashMap::new();
        for c in loaded.checkpoints {
            ckpts_by_flare
                .entry(c.flare_id)
                .or_default()
                .push((c.worker, c.epoch, c.data));
        }

        // Flare records, oldest submission first.
        let mut records: Vec<FlareRecord> = Vec::new();
        for rec_json in &loaded.flares {
            match FlareRecord::from_json(rec_json) {
                Ok(rec) => records.push(rec),
                Err(e) => {
                    stats.skipped += 1;
                    eprintln!("burstc: skipping unreadable flare record: {e}");
                }
            }
        }
        records.sort_by_key(|r| r.submit_seq);
        let mut max_seq = 0u64;
        for mut rec in records {
            max_seq = max_seq.max(rec.submit_seq);
            if rec.status.is_terminal() {
                this.db.put_flare(rec);
                stats.terminal_restored += 1;
                continue;
            }
            // Re-homing: a flare that was placed on (or last ran on) a
            // node that did not re-register has no surviving home for its
            // warm containers or in-flight state — fail it explicitly
            // rather than silently rescheduling it somewhere else.
            if let Some(node) = rec.node.clone() {
                if !this.nodes.has_node(&node) {
                    rec.set_status(FlareStatus::Failed);
                    rec.error = Some(format!(
                        "lost at restart: node '{node}' was not re-registered"
                    ));
                    this.db.put_flare(rec);
                    stats.lost_work += 1;
                    continue;
                }
            }
            match this.rebuild_queued(&rec) {
                Ok(job) => {
                    rec.set_status(FlareStatus::Queued);
                    // A DAG child re-enters the waiting-on-parents area,
                    // not the lanes: completed parents stay done (their
                    // terminal records were restored above, records replay
                    // oldest-first) and the first scheduler pass re-resolves
                    // the edges — failing the child explicitly if a parent
                    // was itself lost at restart.
                    rec.wait_reason = (!job.after.is_empty())
                        .then(|| "waiting_on_parents".to_string());
                    let flare_id = rec.flare_id.clone();
                    this.db.put_flare(rec);
                    // Re-seed the previous process's worker checkpoints
                    // (after `put_flare`: the record must be live) so the
                    // re-run restores instead of recomputing. The epochs
                    // ride along into the db table, where the placement
                    // path picks up their max — run numbering ascends
                    // across the restart.
                    for (worker, epoch, data) in
                        ckpts_by_flare.remove(&flare_id).unwrap_or_default()
                    {
                        this.db.put_checkpoint(&flare_id, worker, epoch, data.into());
                        stats.checkpoints_restored += 1;
                    }
                    this.cancels
                        .lock()
                        .insert(job.flare_id.clone(), job.cancel.clone());
                    let mut q = this.sched.queue.lock();
                    if job.after.is_empty() {
                        q.push(job);
                    } else {
                        q.park_waiting(job);
                    }
                    stats.requeued += 1;
                }
                Err(e) => {
                    let msg = format!("lost at restart: {e}");
                    rec.set_status(FlareStatus::Failed);
                    rec.error = Some(msg);
                    this.db.put_flare(rec);
                    stats.lost_work += 1;
                }
            }
        }
        // Orphaned checkpoints — their flare is terminal, lost at restart,
        // or unknown (e.g. a crash landed between a terminal transition
        // and its drop entry): drop them now so snapshots do not carry
        // dead worker state forever.
        for flare_id in ckpts_by_flare.keys() {
            if let Err(e) =
                store.append_entry(DurableStore::entry_drop_checkpoints(flare_id))
            {
                eprintln!(
                    "burstc: dropping orphaned checkpoints for '{flare_id}' failed: {e}"
                );
            }
        }

        // Flare ids must keep ascending across restarts.
        let next = max_seq + 1;
        this.next_flare.fetch_max(next, Ordering::Relaxed);

        // Compact now: replay re-appended every record to the WAL; fold
        // them into one snapshot so restarts do not accrete log entries.
        if let Err(e) = store.force_snapshot() {
            eprintln!("burstc: post-recovery snapshot failed: {e}");
        }
        *this.recovery.lock() = stats;
        this.sched.resume();
        Ok(this)
    }

    /// Reconstruct the queue entry for a flare that was alive at crash
    /// time, from its persisted record + resubmission spec. Fails (→
    /// explicit `lost at restart`) when the definition or work function
    /// is gone, the spec is unreadable, or the burst no longer fits the
    /// (possibly resized) cluster.
    fn rebuild_queued(&self, rec: &FlareRecord) -> Result<QueuedFlare> {
        let def = self.db.get_def(&rec.def_name)?;
        let work = db::lookup_work(&def.work_name)?;
        let spec = rec
            .spec
            .as_ref()
            .ok_or_else(|| anyhow!("record carries no resubmission spec"))?;
        let params = spec
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("resubmission spec has no params"))?
            .to_vec();
        let burst_size = params.len();
        if burst_size == 0 {
            return Err(anyhow!("resubmission spec has empty params"));
        }
        let capacity = self.nodes.max_node_capacity();
        if burst_size > capacity {
            return Err(anyhow!(
                "flare of {burst_size} workers exceeds total cluster capacity \
                 after restart ({capacity} vCPUs)"
            ));
        }
        let faas = spec.get("faas").and_then(Json::as_bool).unwrap_or(false);
        let granularity = spec
            .get("granularity")
            .and_then(Json::as_usize)
            .unwrap_or(def.conf.granularity);
        let strategy = if faas {
            PackingStrategy::Homogeneous { granularity: 1 }
        } else {
            let name = spec.str_or("strategy", &def.conf.strategy);
            PackingStrategy::parse(name, granularity)
                .ok_or_else(|| anyhow!("unknown packing strategy '{name}'"))?
        };
        let backend = spec
            .get("backend")
            .and_then(Json::as_str)
            .and_then(BackendKind::parse)
            .unwrap_or(def.conf.backend);
        let chunk_size = spec
            .get("chunk_size")
            .and_then(Json::as_usize)
            .unwrap_or(def.conf.chunk_size);
        let preemptible = spec.get("preemptible").and_then(Json::as_bool).unwrap_or(true);
        // Remaining deadline, anchored on the original wall-clock submit
        // time: an already-overdue flare expires on the first pass.
        let deadline = rec.deadline_ms.map(|ms| {
            let elapsed = db::now_unix_ms().saturating_sub(rec.submitted_unix_ms);
            Instant::now() + Duration::from_millis(ms.saturating_sub(elapsed))
        });
        Ok(QueuedFlare {
            flare_id: rec.flare_id.clone(),
            def_name: rec.def_name.clone(),
            work,
            params,
            burst_size,
            strategy,
            backend,
            chunk_size,
            faas,
            tenant: rec.tenant.clone(),
            priority: rec.priority,
            cancel: CancelToken::new(),
            preemptible,
            deadline,
            preempt_count: rec.preempt_count,
            resume_count: rec.resume_count,
            // The placement path derives the run epoch from the restored
            // checkpoints' highest epoch (`checkpoints_for(..).epoch`).
            ckpt_epoch: 0,
            charged: 0.0,
            slot: Arc::new(ResultSlot::new()),
            submitted: crate::util::timing::Stopwatch::start(),
            passed_over: 0,
            quota_blocked: false,
            // Locality: prefer the node that already hosted this flare's
            // warm containers and checkpoints, when it re-registered.
            prior_node: rec.node.clone(),
            infeasible: false,
            // DAG edges ride the record (and thus the WAL): a re-admitted
            // child re-enters the waiting area and re-resolves its parents
            // against the restored records. Parent nodes are re-derived at
            // promotion time, not persisted — the parents may have been
            // re-homed by this very recovery.
            after: rec.after.clone(),
            parent_nodes: Vec::new(),
        })
    }

    /// Route the store's fsync policy knob (`serve --fsync=...`). A no-op
    /// on a controller without a durable store.
    pub fn set_fsync_policy(&self, policy: FsyncPolicy) {
        if let Some(store) = &self.store {
            store.set_fsync_policy(policy);
        }
    }

    /// What recovery replayed (zeroes when the controller started fresh).
    pub fn recovery_stats(&self) -> RecoveryStats {
        *self.recovery.lock()
    }

    /// Convenience: paper-like test platform with a compressed time scale.
    pub fn test_platform(invokers: usize, vcpus: usize, time_scale: f64) -> Arc<Controller> {
        Controller::new(
            ClusterSpec::uniform(invokers, vcpus),
            CostModel::default(),
            NetParams::scaled(time_scale),
        )
    }

    /// Deploy a burst definition (paper Table 2: `deploy`).
    pub fn deploy(&self, name: &str, work_name: &str, conf: BurstConfig) -> Result<()> {
        self.db.deploy(BurstDefinition {
            name: name.to_string(),
            work_name: work_name.to_string(),
            conf,
        })
    }

    pub fn backend(&self, kind: BackendKind) -> Arc<dyn RemoteBackend> {
        let mut v = self.backends.lock();
        if let Some((_, b)) = v.iter().find(|(k, _)| *k == kind) {
            return b.clone();
        }
        let b = kind.build(&self.net);
        v.push((kind, b.clone()));
        b
    }

    /// Data-driven burst sizing (the paper's footnote 5 "future work"):
    /// given an input volume and a per-worker target, suggest a burst size
    /// that fits current free capacity.
    pub fn suggest_burst_size(&self, input_bytes: u64, bytes_per_worker: u64) -> usize {
        let wanted = (input_bytes.div_ceil(bytes_per_worker.max(1))).max(1) as usize;
        // A flare cannot span nodes: clamp to the most free capacity any
        // single alive node has right now.
        let capacity = self
            .nodes
            .node_statuses()
            .iter()
            .filter(|s| s.alive)
            .map(|s| s.free.iter().sum::<usize>())
            .max()
            .unwrap_or(0);
        wanted.min(capacity.max(1))
    }

    /// Submit a flare without blocking (pipeline stages submit → admit →
    /// queue). Validation that can never be cured by waiting — unknown
    /// definition, empty params, a burst larger than *total* cluster
    /// capacity, a granularity no idle invoker could host — fails here,
    /// fast. Anything that merely doesn't fit the *current* load is
    /// admitted and queued; the scheduler places it when capacity frees.
    pub fn submit_flare(
        &self,
        def_name: &str,
        input_params: Vec<Json>,
        opts: &FlareOptions,
    ) -> Result<FlareHandle> {
        let def = self.db.get_def(def_name)?;
        let work = db::lookup_work(&def.work_name)?;
        let burst_size = input_params.len();
        if burst_size == 0 {
            return Err(anyhow!("flare needs at least one input param"));
        }

        // Resolve effective configuration.
        let granularity = if opts.faas {
            1
        } else {
            opts.granularity.unwrap_or(def.conf.granularity)
        };
        let strategy_name = opts.strategy.clone().unwrap_or_else(|| def.conf.strategy.clone());
        let strategy = if opts.faas {
            PackingStrategy::Homogeneous { granularity: 1 }
        } else {
            PackingStrategy::parse(&strategy_name, granularity)
                .ok_or_else(|| anyhow!("unknown packing strategy '{strategy_name}'"))?
        };
        let backend_kind = opts.backend.unwrap_or(def.conf.backend);
        let tenant = opts.tenant.clone().unwrap_or_else(|| DEFAULT_TENANT.to_string());
        let priority = match &opts.priority {
            Some(p) => Priority::parse(p).ok_or_else(|| {
                anyhow!("unknown priority '{p}' (expected low | normal | high)")
            })?,
            None => Priority::Normal,
        };
        let preemptible = opts.preemptible.unwrap_or(true);
        // Queueing deadline: anchored at submission, so a requeued victim
        // keeps its original deadline along with its original submit time.
        let deadline = opts.deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
        // DAG edges: every parent must already be submitted — a typo'd id
        // would otherwise park the child forever. The list is kept in
        // submission order, *not* deduplicated: `parent_input(i)` addresses
        // exactly `after[i]`. A parent may be in any state here (including
        // already failed — the first scheduler pass fails the child fast).
        let after = opts.after.clone();
        for parent in &after {
            if self.db.get_flare(parent).is_none() {
                return Err(anyhow!(
                    "unknown parent flare '{parent}' in `after`: \
                     parents must be submitted before their children"
                ));
            }
        }

        // Admission: a flare that cannot be placed on an *idle* cluster can
        // never run, so reject it now — distinct from "busy, queued". A
        // flare cannot span nodes, so the bound is the largest node.
        let capacity = self.nodes.max_node_capacity();
        if burst_size > capacity {
            return Err(anyhow!(
                "flare of {burst_size} workers exceeds total cluster capacity: \
                 needs {burst_size} vCPUs, cluster has {capacity}"
            ));
        }
        self.nodes.plan_check(strategy, burst_size).map_err(|e| {
            anyhow!("flare can never be placed, even on an idle cluster: {e}")
        })?;

        let submit_seq = self.next_flare.fetch_add(1, Ordering::Relaxed);
        let flare_id = format!("{}-{}", def_name, submit_seq);
        // Resubmission spec: everything a fresh controller needs to
        // re-admit this flare after a crash (see `Controller::recover`).
        // The full params clone is only worth paying for when the record
        // can actually outlive the process.
        let spec = self.db.is_durable().then(|| {
            Json::obj(vec![
                ("params", Json::Arr(input_params.clone())),
                ("granularity", granularity.into()),
                ("strategy", strategy_name.as_str().into()),
                ("backend", backend_kind.name().into()),
                ("chunk_size", def.conf.chunk_size.into()),
                ("faas", opts.faas.into()),
                ("preemptible", preemptible.into()),
            ])
        });
        self.db.put_flare(FlareRecord {
            deadline_ms: opts.deadline_ms,
            submit_seq,
            spec,
            after: after.clone(),
            // A DAG child is admitted but parked outside the lanes until
            // its parents complete; say so on the record from the start.
            wait_reason: (!after.is_empty()).then(|| "waiting_on_parents".to_string()),
            ..FlareRecord::queued(&flare_id, def_name, &tenant, priority)
        });
        let slot = Arc::new(ResultSlot::new());
        let cancel = CancelToken::new();
        self.cancels.lock().insert(flare_id.clone(), cancel.clone());
        // Batched admission: submission only appends to the scheduler's
        // inbox (a short, rarely contended push) — the scheduler adopts
        // the whole batch into the DRR queue at the start of its next
        // pass, so a burst of submitters never serializes on the queue
        // lock a scheduling pass is holding.
        self.sched.inbox.lock().push(QueuedFlare {
            flare_id: flare_id.clone(),
            def_name: def_name.to_string(),
            work,
            params: input_params,
            burst_size,
            strategy,
            backend: backend_kind,
            chunk_size: def.conf.chunk_size,
            faas: opts.faas,
            tenant,
            priority,
            cancel,
            preemptible,
            deadline,
            preempt_count: 0,
            resume_count: 0,
            ckpt_epoch: 0,
            charged: 0.0,
            slot: slot.clone(),
            submitted: crate::util::timing::Stopwatch::start(),
            passed_over: 0,
            quota_blocked: false,
            prior_node: None,
            infeasible: false,
            after,
            parent_nodes: Vec::new(),
        });
        self.sched.wake();
        Ok(FlareHandle { flare_id, slot })
    }

    /// Invoke a burst (paper Table 2: `flare`). The burst size is the
    /// length of `input_params` (§4.2); one worker runs per entry.
    /// Submit-and-wait wrapper over [`Controller::submit_flare`].
    pub fn flare(
        &self,
        def_name: &str,
        input_params: Vec<Json>,
        opts: &FlareOptions,
    ) -> Result<FlareResult> {
        self.submit_flare(def_name, input_params, opts)?.wait()
    }

    /// Live lifecycle status of a submitted flare.
    pub fn flare_status(&self, flare_id: &str) -> Option<FlareStatus> {
        self.db.get_flare(flare_id).map(|r| r.status)
    }

    /// Number of admitted flares currently waiting for capacity,
    /// including submissions still in the admission inbox (they are
    /// queued from the caller's point of view; the scheduler adopts them
    /// at its next pass).
    pub fn queued_flares(&self) -> usize {
        let queued = self.sched.queue.lock().len();
        queued + self.sched.inbox.lock().len()
    }

    /// Queue depth per tenant (lanes with pending flares only, by name),
    /// counting inbox submissions toward their tenant so metrics never
    /// under-report between admission batches.
    pub fn queued_by_tenant(&self) -> Vec<(String, usize)> {
        let mut depth = self.sched.queue.lock().depth_by_tenant();
        let inbox = self.sched.inbox.lock();
        for job in inbox.iter() {
            match depth.iter_mut().find(|(t, _)| *t == job.tenant) {
                Some((_, n)) => *n += 1,
                None => depth.push((job.tenant.clone(), 1)),
            }
        }
        depth
    }

    /// Scheduler hot-path counters: `(passes, admitted, pass_micros)` —
    /// completed scheduling passes, flares admitted from the batched
    /// inbox, and accumulated active pass time in microseconds. The
    /// sustained-load bench derives scheduler-pass cost and batch sizes
    /// from these (exported on `/metrics`).
    pub fn scheduler_pass_stats(&self) -> (u64, u64, u64) {
        (
            self.sched.passes.load(Ordering::Relaxed),
            self.sched.admitted.load(Ordering::Relaxed),
            self.sched.pass_micros.load(Ordering::Relaxed),
        )
    }

    /// Queued flares currently waiting on their tenant's hard vCPU quota.
    pub fn quota_blocked_flares(&self) -> usize {
        self.sched.queue.lock().quota_blocked_ids().len()
    }

    /// Set a tenant's fair-share weight (a weight-2 lane is entitled to
    /// twice the placed vCPUs of a weight-1 lane). Persisted when a
    /// durable store is attached.
    pub fn set_tenant_weight(&self, tenant: &str, weight: f64) {
        let policy = {
            let mut q = self.sched.queue.lock();
            q.set_tenant_weight(tenant, weight);
            q.policy(tenant)
        };
        self.persist_tenant(tenant, policy);
    }

    /// Set (or clear, with `None`) a tenant's hard cap on concurrently
    /// placed vCPUs. A flare over the cap is admitted but waits with a
    /// `quota_blocked` reason, even when the cluster has free capacity.
    /// Persisted when a durable store is attached.
    pub fn set_tenant_quota(&self, tenant: &str, quota: Option<usize>) {
        let policy = {
            let mut q = self.sched.queue.lock();
            q.set_tenant_quota(tenant, quota);
            q.policy(tenant)
        };
        self.persist_tenant(tenant, policy);
        // A lifted / raised quota may unblock waiting flares immediately.
        self.sched.wake();
    }

    /// Every tenant lane's policy and live usage (the `/v1/tenants` view).
    pub fn tenant_policies(&self) -> Vec<TenantPolicy> {
        self.sched.queue.lock().tenant_policies()
    }

    fn persist_tenant(&self, tenant: &str, policy: Option<(f64, Option<usize>)>) {
        let (Some(store), Some((weight, quota))) = (&self.store, policy) else {
            return;
        };
        if let Err(e) = store.append_tenant(tenant, weight, quota) {
            eprintln!("burstc: WAL append failed for tenant '{tenant}' policy: {e}");
        }
    }

    /// Reconcile wait reasons in the flare records with the queue's latest
    /// scan: `quota_blocked` (tenant hard cap) and `no_feasible_node`
    /// (aggregate capacity suffices, but no single node can host the flare
    /// — or every candidate refused within the spillback budget). Called
    /// from the scheduler pass; writes — and WAL entries — happen only on
    /// transitions.
    pub(crate) fn sync_wait_reasons(&self) {
        let (quota, infeasible) = {
            let q = self.sched.queue.lock();
            (q.quota_blocked_ids(), q.infeasible_ids())
        };
        let mut now: HashMap<String, &'static str> = HashMap::new();
        for id in quota {
            now.insert(id, "quota_blocked");
        }
        for id in infeasible {
            now.entry(id).or_insert("no_feasible_node");
        }
        let mut marked = self.wait_marked.lock();
        for (id, reason) in &now {
            if marked.get(id) != Some(reason) {
                self.db.update_flare(id, |r| {
                    if r.status == FlareStatus::Queued {
                        r.wait_reason = Some((*reason).into());
                    }
                });
            }
        }
        for (id, reason) in marked.iter() {
            if !now.contains_key(id) {
                self.db.update_flare(id, |r| {
                    if r.status == FlareStatus::Queued
                        && r.wait_reason.as_deref() == Some(reason)
                    {
                        r.wait_reason = None;
                    }
                });
            }
        }
        *marked = now;
    }

    /// Settle a lane's provisional placement charge to measured usage and
    /// persist the tenant's new lifetime vCPU·second total. The WAL entry
    /// carries the *absolute* total, so replay is an idempotent overwrite
    /// (`GET /v1/tenants/<id>/usage` survives restarts).
    fn settle_usage(&self, tenant: &str, provisional: f64, measured: f64) {
        let total = self.sched.queue.lock().settle(tenant, provisional, measured);
        if let Some(store) = &self.store {
            if let Err(e) = store.append_entry(DurableStore::entry_usage(tenant, total)) {
                eprintln!("burstc: WAL append failed for tenant '{tenant}' usage: {e}");
            }
        }
    }

    /// Lifetime settled vCPU·seconds billed to a tenant (`None`: the
    /// tenant has no lane — it never submitted and has no policy).
    pub fn tenant_usage(&self, tenant: &str) -> Option<f64> {
        self.sched.queue.lock().usage_of(tenant)
    }

    /// Drop a terminal flare's cancel token from the kill-path registry.
    fn clear_cancel(&self, flare_id: &str) {
        self.cancels.lock().remove(flare_id);
    }

    /// The kill path (`DELETE /v1/flares/<id>`). A queued flare is removed
    /// before it can be placed and its waiter fails fast; a running flare
    /// has its [`CancelToken`] tripped, which `run_flare_packs` and
    /// `BurstContext` observe cooperatively at phase boundaries so the
    /// reservation is released promptly. Cancelling a terminal flare is a
    /// conflict, an unknown id is not found.
    pub fn cancel_flare(&self, flare_id: &str) -> Result<CancelOutcome, CancelError> {
        // Fast path: still waiting — in the admission inbox (submitted,
        // not yet adopted by a scheduling pass) or in the queue proper —
        // → pull it out before it is ever placed.
        let inboxed = {
            let mut inbox = self.sched.inbox.lock();
            inbox
                .iter()
                .position(|j| j.flare_id == flare_id)
                .map(|i| inbox.remove(i))
        };
        let queued = inboxed.or_else(|| self.sched.queue.lock().remove(flare_id));
        if let Some(job) = queued {
            job.cancel.cancel();
            self.db.update_flare(flare_id, |r| {
                if r.set_status(FlareStatus::Cancelled) {
                    r.error = Some("cancelled while queued".into());
                }
            });
            self.clear_cancel(flare_id);
            // A cancelled flare frees its (virtual) spot: re-scan the queue.
            self.sched.wake();
            job.slot
                .deliver(Err(anyhow!("flare '{flare_id}' cancelled while queued")));
            return Ok(CancelOutcome::CancelledQueued);
        }
        // Placed (or being placed): trip the token; the execution thread
        // observes it at the next phase boundary / cancellation point.
        // The trip happens *under* the registry lock: the preempt-requeue
        // path swaps in a fresh token under the same lock, so the user
        // kill either lands on the old token before the swap decision
        // (requeue aborts, terminal `Cancelled`) or on the fresh token
        // after it (caught at the next placement's pre-check) — it can
        // never fall between and be lost.
        {
            let cancels = self.cancels.lock();
            if let Some(t) = cancels.get(flare_id) {
                t.cancel();
                return Ok(CancelOutcome::CancellingRunning);
            }
        }
        match self.db.get_flare(flare_id) {
            Some(rec) => Err(CancelError::AlreadyTerminal(rec.status)),
            None => Err(CancelError::NotFound),
        }
    }

    /// Preemption policy knobs: enable or disable scheduler-initiated
    /// preemption, and cap how many times one flare may be preempted and
    /// requeued (the livelock guard — at the cap a flare stops being
    /// selectable as a victim and runs to completion).
    pub fn set_preemption_policy(&self, enabled: bool, max_preempts: u32) {
        self.preempt_enabled.store(enabled, Ordering::Relaxed);
        self.max_preempts.store(max_preempts, Ordering::Relaxed);
    }

    /// Lifetime count of scheduler-initiated preemptions.
    pub fn preemptions(&self) -> u64 {
        self.preempted_total.load(Ordering::Relaxed)
    }

    /// Lifetime count of flares that expired while queued.
    pub fn expirations(&self) -> u64 {
        self.expired_total.load(Ordering::Relaxed)
    }

    /// Lifetime count of flare runs that *resumed* from prior worker
    /// checkpoints (after a preemption or a crash recovery).
    pub fn resumes(&self) -> u64 {
        self.resumed_total.load(Ordering::Relaxed)
    }

    /// Fail fast every queued flare whose deadline lapsed (scheduler pass):
    /// terminal [`FlareStatus::Expired`], waiter unblocked with an error.
    pub(crate) fn expire_overdue_queued(&self) {
        let expired = self.sched.queue.lock().take_expired(Instant::now());
        for job in expired {
            self.expired_total.fetch_add(1, Ordering::Relaxed);
            let e = anyhow!(
                "flare '{}' expired: deadline passed after {:.3}s queued",
                job.flare_id,
                job.submitted.secs()
            );
            self.db.update_flare(&job.flare_id, |r| {
                if r.set_status(FlareStatus::Expired) {
                    r.error = Some(e.to_string());
                }
            });
            self.clear_cancel(&job.flare_id);
            job.slot.deliver(Err(e));
        }
    }

    /// DAG admission pass (scheduler loop, before placement): resolve
    /// every flare parked in the waiting-on-parents holding area against
    /// its parents' current status. A child whose parents all reached
    /// `Completed` is promoted into the DRR lanes carrying the parents'
    /// nodes, so the placer's DAG-locality term stages it where the
    /// outputs live. A child with a parent in any other terminal state —
    /// or whose parent record is gone (lost at restart, or evicted by
    /// retention) — fails fast with [`FlareStatus::ParentFailed`], naming
    /// the parent and why. That failure is itself terminal-non-completed,
    /// so it fails *its* children on the next pass: a cancellation fans
    /// out through every descendant, each failed exactly once (the take
    /// from the waiting area is the uniqueness point).
    pub(crate) fn resolve_dag_waiters(&self) {
        let edges = self.sched.queue.lock().waiting_edges();
        if edges.is_empty() {
            return;
        }
        // Verdicts are computed against the db *without* the queue lock:
        // parent status reads take shard read locks and must not stall a
        // submit burst behind the scheduler.
        enum Verdict {
            Promote(Vec<String>),
            Fail(String),
        }
        let mut verdicts: Vec<(String, Verdict)> = Vec::new();
        'child: for (id, after) in edges {
            let mut parent_nodes = Vec::new();
            for parent in &after {
                match self.db.get_flare(parent) {
                    Some(rec) if rec.status == FlareStatus::Completed => {
                        // One entry per parent (not deduped): the placer
                        // weights multi-parent affinity by fraction.
                        if let Some(n) = rec.node {
                            parent_nodes.push(n);
                        }
                    }
                    Some(rec) if rec.status.is_terminal() => {
                        let why = format!(
                            "parent flare '{parent}' {}{}",
                            rec.status.name(),
                            rec.error
                                .map(|e| format!(": {e}"))
                                .unwrap_or_default()
                        );
                        verdicts.push((id, Verdict::Fail(why)));
                        continue 'child;
                    }
                    Some(_) => continue 'child, // parent live: keep waiting
                    None => {
                        let why = format!(
                            "parent flare '{parent}' is gone \
                             (lost at restart or evicted)"
                        );
                        verdicts.push((id, Verdict::Fail(why)));
                        continue 'child;
                    }
                }
            }
            verdicts.push((id, Verdict::Promote(parent_nodes)));
        }
        for (id, verdict) in verdicts {
            // Re-take under the queue lock: a user cancel may have pulled
            // the child out of the waiting area since the snapshot — it
            // won, and the slot was already delivered exactly once.
            let Some(mut job) = self.sched.queue.lock().take_waiting(&id) else {
                continue;
            };
            match verdict {
                Verdict::Promote(parent_nodes) => {
                    job.parent_nodes = parent_nodes;
                    self.db.update_flare(&id, |r| {
                        if r.status == FlareStatus::Queued {
                            r.wait_reason = None;
                        }
                    });
                    self.sched.queue.lock().push(job);
                }
                Verdict::Fail(why) => {
                    let e = anyhow!("flare '{id}' failed before starting: {why}");
                    self.db.update_flare(&id, |r| {
                        if r.set_status(FlareStatus::ParentFailed) {
                            r.error = Some(e.to_string());
                        }
                    });
                    self.clear_cancel(&id);
                    // Grandchildren fail on the *next* pass — wake it now
                    // so a deep chain collapses promptly instead of one
                    // level per poll tick.
                    self.sched.wake();
                    job.slot.deliver(Err(e));
                }
            }
        }
    }

    /// Scheduler-initiated preemption: if a `high` flare is starved (it
    /// cannot be placed and no placement is pending that would free
    /// enough), select victims among running lower-priority preemptible
    /// flares and trip their tokens with the `Preempted` reason. The
    /// workers unwind at their next cancellation point, the reservation is
    /// released, and the victim is requeued at the head of its lane.
    pub(crate) fn preempt_for_starved_high_flare(&self) {
        if !self.preempt_enabled.load(Ordering::Relaxed) {
            return;
        }
        let starved = self.sched.queue.lock().oldest_of_class(Priority::High);
        let Some(burst_size) = starved else { return };
        let max = self.max_preempts.load(Ordering::Relaxed);
        let mut running = self.running.lock();
        // vCPUs already being reclaimed by in-flight preemptions count as
        // covered *on their node*: successive scheduler passes must not
        // pile on victims, and reclaim on node A cannot unblock node B.
        let mut inflight_by_node: HashMap<&str, usize> = HashMap::new();
        for r in running.values().filter(|r| r.preempting) {
            *inflight_by_node.entry(r.node.as_str()).or_insert(0) += r.vcpus;
        }
        // Per-node shortfall, over nodes that could host the flare at all:
        // freeing that much *contiguous* capacity there makes it placeable.
        let mut needed_by_node: BTreeMap<String, usize> = BTreeMap::new();
        for s in self.nodes.node_statuses() {
            if !s.alive || s.total.iter().sum::<usize>() < burst_size {
                continue;
            }
            let free: usize = s.free.iter().sum();
            let covered = free + inflight_by_node.get(s.name.as_str()).copied().unwrap_or(0);
            if covered >= burst_size {
                return; // some node already (or soon) has room
            }
            needed_by_node.insert(s.name, burst_size - covered);
        }
        if needed_by_node.is_empty() {
            return;
        }
        let cands: Vec<PreemptCandidate> = running
            .iter()
            .filter(|(_, r)| {
                !r.preempting
                    && r.preemptible
                    && r.preempt_count < max
                    && r.priority < Priority::High
            })
            .map(|(id, r)| PreemptCandidate {
                flare_id: id.clone(),
                priority: r.priority,
                vcpus: r.vcpus,
                seq: r.seq,
                node: r.node.clone(),
            })
            .collect();
        for id in select_victims(&cands, &needed_by_node) {
            if let Some(r) = running.get_mut(&id) {
                r.preempting = true;
                r.cancel.preempt();
                self.preempted_total.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Node liveness pass (scheduler loop): drive in-process heartbeats,
    /// declare silent nodes dead once their miss budget is exhausted, and
    /// fail over the dead nodes' running flares — their tokens trip with
    /// the `Preempted` reason (regardless of the preemptible flag: the
    /// *node* is gone, not reclaimed), so each unwinds and requeues to be
    /// re-placed on a surviving node, resuming from its checkpoints. Not
    /// counted as scheduler preemptions in `/metrics`.
    pub(crate) fn node_maintenance(&self) {
        self.nodes.pulse();
        let dead = self.nodes.reap_dead();
        if dead.is_empty() {
            return;
        }
        let mut running = self.running.lock();
        for r in running.values_mut() {
            if dead.contains(&r.node) && !r.preempting {
                r.preempting = true;
                r.cancel.preempt();
            }
        }
    }

    /// Run a placed flare on its own thread (pipeline stage execute). The
    /// pack reservation is already held; it is released when work ends,
    /// then the scheduler is woken to place queued flares into the freed
    /// capacity, and only then is the result delivered to the submitter.
    pub(crate) fn spawn_execution(
        this: &Arc<Controller>,
        job: QueuedFlare,
        placement: NodePlacement,
        sched: &Arc<SchedState>,
    ) {
        let c = this.clone();
        let sched = sched.clone();
        // The payload round-trips through an Arc so a failed thread spawn
        // (fd/thread exhaustion under heavy burst load) can recover the
        // job, fail it cleanly, and release the reservation — panicking
        // here would kill the scheduler loop and hang every waiter.
        let name = format!("flare-{}", job.flare_id);
        let payload = Arc::new(RankedMutex::new(LockRank::Leaf, Some((job, placement))));
        let payload2 = payload.clone();
        let spawned = std::thread::Builder::new().name(name).spawn(move || {
            let (mut job, placement) = payload2.lock().take().expect("payload set");
            // Cancel raced the pop→spawn window: release untouched capacity
            // and finish as `Cancelled` without ever starting the packs.
            if job.cancel.is_cancelled() {
                c.nodes.release(&placement.node, &placement.packs);
                // The lane was provisionally charged at placement; the
                // flare never ran, so the measured usage settles to zero.
                c.settle_usage(&job.tenant, job.charged, 0.0);
                let e = anyhow!("flare '{}' cancelled before placement", job.flare_id);
                c.db.update_flare(&job.flare_id, |r| {
                    if r.set_status(FlareStatus::Cancelled) {
                        r.error = Some(e.to_string());
                    }
                });
                c.clear_cancel(&job.flare_id);
                sched.wake();
                job.slot.deliver(Err(e));
                return;
            }
            // Register with the preemption policy's view of the cluster.
            let seq = c.next_seq.fetch_add(1, Ordering::Relaxed);
            c.running.lock().insert(
                job.flare_id.clone(),
                RunningFlare {
                    priority: job.priority,
                    vcpus: job.burst_size,
                    seq,
                    preemptible: job.preemptible,
                    preempt_count: job.preempt_count,
                    cancel: job.cancel.clone(),
                    preempting: false,
                    node: placement.node.clone(),
                },
            );
            // Locality hint for the *next* placement of this flare (a
            // preempt-requeue or post-restart re-admission): its warm
            // containers and checkpoints live on this node now.
            job.prior_node = Some(placement.node.clone());
            // Checkpoint/resume: hand back whatever the previous run (a
            // preempted one, or the pre-crash process after recovery) left
            // behind, and number this run's epoch past every restored one.
            let prior_ckpts = c.db.checkpoints_for(&job.flare_id);
            let resumed = !prior_ckpts.by_worker.is_empty();
            if resumed {
                job.resume_count += 1;
                c.resumed_total.fetch_add(1, Ordering::Relaxed);
            }
            job.ckpt_epoch = job.ckpt_epoch.max(prior_ckpts.epoch) + 1;
            let ckpt_channel = {
                let cc = c.clone();
                let flare_id = job.flare_id.clone();
                let epoch = job.ckpt_epoch;
                let prior: HashMap<usize, Bytes> =
                    prior_ckpts.by_worker.into_iter().collect();
                CheckpointChannel::new(prior, move |worker, bytes| {
                    cc.db.put_checkpoint(&flare_id, worker, epoch, Arc::new(bytes));
                })
            };
            let queue_wait_s = job.submitted.secs();
            let resume_count = job.resume_count;
            c.db.update_flare(&job.flare_id, |r| {
                if r.set_status(FlareStatus::Running) {
                    r.wait_reason = None;
                    r.resume_count = resume_count;
                    // Explainable placement: which node won, at what score,
                    // and why each other candidate was rejected.
                    r.node = Some(placement.node.clone());
                    r.placement = Some(placement.decision.clone());
                }
            });
            // A panic must neither strand the waiter in `wait()` nor
            // leak the reservation (released by guard inside).
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                c.execute_placed(
                    &job,
                    &placement.node,
                    placement.packs,
                    queue_wait_s,
                    &ckpt_channel,
                )
            }))
            .unwrap_or_else(|_| {
                let e = anyhow!("flare '{}' execution panicked", job.flare_id);
                c.db.update_flare(&job.flare_id, |r| {
                    if r.set_status(FlareStatus::Failed) {
                        r.error = Some(e.to_string());
                    }
                });
                Err(e)
            });
            c.running.lock().remove(&job.flare_id);
            // A preempted flare (and only a preempted one — a user kill
            // wins when both raced) is requeued instead of completing.
            // `execute_placed` read the token earlier than this check, so
            // a trip landing between the two reads can desynchronize the
            // record from the decision; the db record's terminality is
            // the arbiter. A flare whose record already went terminal
            // (e.g. work genuinely failed, then a preempt trip raced in)
            // must never be resurrected by the requeue path.
            let record_terminal = c
                .db
                .get_flare(&job.flare_id)
                .is_some_and(|r| r.status.is_terminal());
            if result.is_err()
                && !record_terminal
                && job.cancel.reason() == Some(CancelReason::Preempted)
            {
                Controller::requeue_preempted(&c, job);
                return;
            }
            c.clear_cancel(&job.flare_id);
            if let Err(e) = &result {
                // The inverse race: `execute_placed` saw `Preempted` (so
                // it left the record alone for the requeue), but a user
                // cancel tripped before the check above. Without this the
                // record would be stuck `Running` forever — unkillable,
                // never evicted, re-admitted after a restart.
                let to = if job.cancel.user_cancelled() {
                    FlareStatus::Cancelled
                } else {
                    FlareStatus::Failed
                };
                c.db.update_flare(&job.flare_id, |r| {
                    // `set_status` refuses terminal rewrites, which is
                    // exactly the old `!is_terminal()` guard.
                    if r.set_status(to) {
                        r.error = Some(e.to_string());
                    }
                });
            }
            sched.wake();
            job.slot.deliver(result);
        });
        if spawned.is_err() {
            // Take the payload *before* the `if let` so the lock guard is
            // dropped ahead of the lower-ranked node-registry acquisition
            // inside (if-let scrutinee temporaries live to the end of the
            // block).
            let recovered = payload.lock().take();
            if let Some((job, placement)) = recovered {
                this.nodes.release(&placement.node, &placement.packs);
                this.settle_usage(&job.tenant, job.charged, 0.0);
                let e = anyhow!(
                    "could not spawn execution thread for flare '{}'",
                    job.flare_id
                );
                this.db.update_flare(&job.flare_id, |r| {
                    if r.set_status(FlareStatus::Failed) {
                        r.error = Some(e.to_string());
                    }
                });
                this.clear_cancel(&job.flare_id);
                // The freed capacity must reach queued flares now, not at
                // the scheduler's next poll timeout.
                this.sched.wake();
                job.slot.deliver(Err(e));
            }
        }
    }

    /// A preempted flare has unwound and released its reservation: put it
    /// back at the head of its lane with a fresh token, its original
    /// submit time, and `preempt_count + 1` — unless a user cancel raced
    /// the requeue, in which case terminal `Cancelled` wins and the flare
    /// is never resurrected.
    fn requeue_preempted(this: &Arc<Controller>, mut job: QueuedFlare) {
        let fresh = CancelToken::new();
        {
            // `cancel_flare` trips the registered token while holding this
            // lock, so exactly one of two things is true when we decide:
            // the user bit is already on the old token (abort the requeue
            // below), or any later cancel lands on the fresh token and is
            // caught at the next placement's pre-check.
            let mut cancels = this.cancels.lock();
            if job.cancel.user_cancelled() {
                cancels.remove(&job.flare_id);
                drop(cancels);
                let e = anyhow!("flare '{}' cancelled", job.flare_id);
                this.db.update_flare(&job.flare_id, |r| {
                    if r.set_status(FlareStatus::Cancelled) {
                        r.error = Some(e.to_string());
                    }
                });
                this.sched.wake();
                job.slot.deliver(Err(e));
                return;
            }
            cancels.insert(job.flare_id.clone(), fresh.clone());
        }
        let flare_id = job.flare_id.clone();
        let slot = job.slot.clone();
        job.cancel = fresh.clone();
        job.preempt_count += 1;
        let preempt_count = job.preempt_count;
        this.db.update_flare(&flare_id, |r| {
            if r.set_status(FlareStatus::Queued) {
                r.preempt_count = preempt_count;
                r.error = None;
            }
        });
        this.sched.queue.lock().requeue_preempted(job);
        this.sched.wake();
        // A user cancel can land in the swap→push window above: it finds
        // neither a queued job to remove nor an execution to unwind, only
        // the fresh token. Re-check after the push so that kill finishes
        // now — not at the next successful placement's pre-check, which a
        // saturated cluster could postpone indefinitely. (A cancel landing
        // after the push is handled by `cancel_flare` itself: exactly one
        // side wins the queue removal.)
        if fresh.user_cancelled() && this.sched.queue.lock().remove(&flare_id).is_some() {
            let e = anyhow!("flare '{flare_id}' cancelled");
            this.db.update_flare(&flare_id, |r| {
                if r.set_status(FlareStatus::Cancelled) {
                    r.error = Some(e.to_string());
                }
            });
            this.clear_cancel(&flare_id);
            slot.deliver(Err(e));
        }
    }

    /// Pipeline stages execute → complete, with the reservation held.
    fn execute_placed(
        &self,
        job: &QueuedFlare,
        node: &str,
        packs: Vec<PackSpec>,
        queue_wait_s: f64,
        ckpt: &Arc<CheckpointChannel>,
    ) -> Result<FlareResult> {
        // Release the reservation exactly once, even if something on this
        // thread panics mid-flare. Routing through the registry re-syncs
        // the node's cluster-side view, so freed capacity is immediately
        // placeable.
        struct ReleaseOnDrop<'a> {
            nodes: &'a NodeRegistry,
            node: &'a str,
            packs: Option<Vec<PackSpec>>,
        }
        impl ReleaseOnDrop<'_> {
            fn release_now(&mut self) -> Vec<PackSpec> {
                let packs = self.packs.take().expect("released once");
                self.nodes.release(self.node, &packs);
                packs
            }
        }
        impl Drop for ReleaseOnDrop<'_> {
            fn drop(&mut self) {
                if let Some(p) = self.packs.take() {
                    self.nodes.release(self.node, &p);
                }
            }
        }
        let mut reservation =
            ReleaseOnDrop { nodes: self.nodes.as_ref(), node, packs: Some(packs) };
        let packs = reservation.packs.as_ref().expect("held");

        // Modeled start-up latencies (container creation dominates, §5.1).
        let startup = {
            let mut rng = self.rng.lock();
            model_startup(packs, &self.cost, job.faas, &mut rng)
        };
        let topo = PackTopology::new(
            packs.iter().map(|p| p.workers.clone()).collect(),
            packs.iter().map(|p| p.invoker_id).collect(),
        );
        let fabric = CommFabric::new(
            &job.flare_id,
            topo,
            self.backend(job.backend),
            &self.net,
            FabricConfig {
                chunk_size: job.chunk_size,
                // Workers blocked inside a collective unwind at a
                // cancel/preempt trip, not after the fabric timeout.
                cancel: Some(job.cancel.clone()),
                ..FabricConfig::default()
            },
        );

        // DAG input staging: publish each parent's outputs under this
        // flare's own key prefix *before* any worker starts, so
        // `BurstContext::parent_input(i)` can read `after[i]`'s results
        // without ordering hazards. Published read-many (any worker, any
        // pack) and torn down with the rest of the flare's backend state.
        for (idx, parent) in job.after.iter().enumerate() {
            let outputs = self
                .db
                .get_flare(parent)
                .map(|r| Json::Arr(r.outputs))
                .unwrap_or(Json::Arr(Vec::new()));
            fabric.stage_dag_input(idx, outputs.to_string().into_bytes())?;
        }

        let timeline = Arc::new(Timeline::new());
        let sw = crate::util::timing::Stopwatch::start();
        let result = run_flare_packs(
            packs,
            &fabric,
            &job.work,
            &job.params,
            &startup,
            &timeline,
            queue_wait_s,
            &job.cancel,
            ckpt,
        );
        let work_wall_s = sw.secs();
        fabric.teardown();
        let packs = reservation.release_now();
        // Settle the lane's provisional placement charge to the measured
        // vCPU·seconds the reservation was actually held (bugfix: a flare
        // that failed, was cancelled, or was preempted early must not be
        // billed as if it ran to completion), and persist the tenant's new
        // lifetime usage total.
        self.settle_usage(&job.tenant, job.charged, job.burst_size as f64 * work_wall_s);
        match result {
            Ok(outputs) => {
                let res = FlareResult {
                    flare_id: job.flare_id.clone(),
                    outputs,
                    packs,
                    startup,
                    timeline,
                    traffic: fabric.traffic.clone(),
                    backend_name: fabric.backend_name(),
                    work_wall_s,
                    queue_wait_s,
                };
                self.db.update_flare(&job.flare_id, |r| {
                    if r.set_status(FlareStatus::Completed) {
                        r.outputs = res.outputs.clone();
                        r.metadata = res.summary_json();
                    }
                });
                Ok(res)
            }
            Err(e) => {
                // A failure caused by the kill path is `Cancelled`, not
                // `Failed` — the distinction is terminal and observable.
                // A *preempt* unwind is not terminal at all: the spawn
                // thread requeues the flare, so leave the record alone.
                let status = match job.cancel.reason() {
                    Some(CancelReason::Preempted) => None,
                    Some(CancelReason::User) => Some(FlareStatus::Cancelled),
                    None => Some(FlareStatus::Failed),
                };
                if let Some(status) = status {
                    self.db.update_flare(&job.flare_id, |r| {
                        if r.set_status(status) {
                            r.error = Some(e.to_string());
                        }
                    });
                }
                Err(e)
            }
        }
    }
}

impl Drop for Controller {
    fn drop(&mut self) {
        self.sched.shutdown();
        if let Some(h) = self.sched_thread.lock().take() {
            // The scheduler's own `Weak::upgrade` can make it the thread
            // that drops the last `Arc<Controller>`; never self-join — the
            // shutdown flag alone ends the loop.
            if h.thread().id() != std::thread::current().id() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc as StdArc;

    fn register_echo() {
        db::register_work(
            "ctrl-echo",
            StdArc::new(|p: &Json, ctx: &crate::bcm::BurstContext| {
                Ok(Json::obj(vec![
                    ("w", ctx.worker_id.into()),
                    ("g", ctx.granularity().into()),
                    ("p", p.clone()),
                ]))
            }),
        );
    }

    fn register_allreduce() {
        db::register_work(
            "ctrl-allreduce",
            StdArc::new(|_p: &Json, ctx: &crate::bcm::BurstContext| {
                let f = |a: &mut Vec<u8>, b: &[u8]| {
                    let x = u64::from_le_bytes(a.as_slice().try_into().unwrap());
                    let y = u64::from_le_bytes(b.try_into().unwrap());
                    *a = (x + y).to_le_bytes().to_vec();
                };
                let r = ctx.reduce(0, (ctx.worker_id as u64).to_le_bytes().to_vec(), &f)?;
                // All-reduce: re-broadcast the reduce result's shared buffer
                // without copying it.
                let sum = if ctx.worker_id == 0 {
                    ctx.broadcast_shared(0, Some(r.unwrap()))?
                } else {
                    ctx.broadcast_shared(0, None)?
                };
                Ok(Json::Num(u64::from_le_bytes(sum.as_slice().try_into().unwrap()) as f64))
            }),
        );
    }

    #[test]
    fn deploy_and_flare_end_to_end() {
        register_echo();
        let c = Controller::test_platform(2, 48, 1e-6);
        c.deploy("echo", "ctrl-echo", BurstConfig { granularity: 4, ..Default::default() })
            .unwrap();
        let params: Vec<Json> = (0..10).map(|i| Json::Num(i as f64)).collect();
        let r = c.flare("echo", params, &FlareOptions::default()).unwrap();
        assert_eq!(r.outputs.len(), 10);
        for (i, o) in r.outputs.iter().enumerate() {
            assert_eq!(o.get("w").unwrap().as_usize(), Some(i));
            assert_eq!(o.get("p").unwrap().as_f64(), Some(i as f64));
        }
        assert!(r.startup.all_ready_s > 0.0);
        // Record stored in db, in terminal state, with queue wait measured.
        let rec = c.db.get_flare(&r.flare_id).unwrap();
        assert_eq!(rec.status, FlareStatus::Completed);
        assert!(rec.metadata.get("queue_wait_s").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn submit_flare_is_nonblocking_and_trackable() {
        register_echo();
        let c = Controller::test_platform(2, 48, 1e-6);
        c.deploy("sub", "ctrl-echo", BurstConfig { granularity: 4, ..Default::default() })
            .unwrap();
        let h = c
            .submit_flare("sub", vec![Json::Null; 8], &FlareOptions::default())
            .unwrap();
        // Submission recorded immediately, in a live (or terminal) state.
        assert!(c.flare_status(&h.flare_id).is_some());
        let r = h.wait().unwrap();
        assert_eq!(r.outputs.len(), 8);
        assert_eq!(c.flare_status(&r.flare_id), Some(FlareStatus::Completed));
    }

    #[test]
    fn flare_with_collectives_across_packs() {
        register_allreduce();
        let c = Controller::test_platform(2, 48, 1e-6);
        c.deploy(
            "ar",
            "ctrl-allreduce",
            BurstConfig {
                granularity: 3,
                strategy: "homogeneous".into(), // mixed would merge same-invoker packs
                ..Default::default()
            },
        )
        .unwrap();
        let r = c
            .flare("ar", vec![Json::Null; 9], &FlareOptions::default())
            .unwrap();
        let expected: f64 = (0..9).sum::<usize>() as f64;
        assert!(r.outputs.iter().all(|o| o.as_f64() == Some(expected)));
        assert_eq!(r.packs.len(), 3);
        assert!(r.traffic.remote() > 0);
    }

    #[test]
    fn faas_option_forces_granularity_one() {
        register_echo();
        let c = Controller::test_platform(2, 48, 1e-6);
        c.deploy("e2", "ctrl-echo", BurstConfig { granularity: 8, ..Default::default() })
            .unwrap();
        let opts = FlareOptions { faas: true, ..Default::default() };
        let r = c.flare("e2", vec![Json::Null; 6], &opts).unwrap();
        assert_eq!(r.packs.len(), 6);
        // FaaS invocation latency must exceed a burst flare's.
        let rb = c
            .flare(
                "e2",
                vec![Json::Null; 6],
                &FlareOptions { granularity: Some(6), ..Default::default() },
            )
            .unwrap();
        assert!(r.startup.all_ready_s > rb.startup.all_ready_s);
    }

    #[test]
    fn resources_released_after_flare() {
        register_echo();
        let c = Controller::test_platform(1, 16, 1e-6);
        c.deploy("e3", "ctrl-echo", BurstConfig::default()).unwrap();
        for _ in 0..3 {
            // 16 workers fill the invoker completely; must succeed 3×.
            let r = c
                .flare(
                    "e3",
                    vec![Json::Null; 16],
                    &FlareOptions { granularity: Some(16), ..Default::default() },
                )
                .unwrap();
            assert_eq!(r.outputs.len(), 16);
        }
        assert_eq!(c.pool.free_vcpus(), vec![16]);
    }

    #[test]
    fn oversized_flare_rejected() {
        register_echo();
        let c = Controller::test_platform(1, 4, 1e-6);
        c.deploy("e4", "ctrl-echo", BurstConfig::default()).unwrap();
        // Larger than *total* cluster capacity: fails fast at submit, with
        // an error naming required vs available vCPUs — not "busy, queued".
        let err = c
            .flare("e4", vec![Json::Null; 10], &FlareOptions::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("10 vCPUs"), "{err}");
        assert!(err.contains("cluster has 4"), "{err}");
        assert_eq!(c.pool.free_vcpus(), vec![4]);
    }

    #[test]
    fn impossible_granularity_rejected_at_submit() {
        register_echo();
        // Homogeneous granularity-8 packs can never fit 4-vCPU invokers,
        // even idle — reject at submit instead of queueing forever.
        let c = Controller::test_platform(2, 4, 1e-6);
        c.deploy(
            "e5",
            "ctrl-echo",
            BurstConfig {
                granularity: 8,
                strategy: "homogeneous".into(),
                ..Default::default()
            },
        )
        .unwrap();
        let err = c
            .flare("e5", vec![Json::Null; 8], &FlareOptions::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("never be placed"), "{err}");
        assert_eq!(c.pool.free_vcpus(), vec![4, 4]);
    }

    #[test]
    fn smart_burst_sizing_fits_capacity() {
        let c = Controller::test_platform(2, 8, 1e-6);
        // 100 MiB at 10 MiB/worker = 10 workers, fits 16 vCPUs.
        assert_eq!(c.suggest_burst_size(100 << 20, 10 << 20), 10);
        // Capacity-clamped.
        assert_eq!(c.suggest_burst_size(1 << 40, 1 << 20), 16);
        // Tiny inputs still get one worker.
        assert_eq!(c.suggest_burst_size(1, 1 << 20), 1);
    }

    #[test]
    fn placement_is_recorded_on_the_flare_record() {
        register_echo();
        let c = Controller::new_multi(
            vec![
                ("node-0".into(), ClusterSpec::uniform(1, 4)),
                ("node-1".into(), ClusterSpec::uniform(1, 8)),
            ],
            CostModel::default(),
            NetParams::scaled(1e-6),
        );
        c.deploy("place", "ctrl-echo", BurstConfig::default()).unwrap();
        let r = c.flare("place", vec![Json::Null; 8], &FlareOptions::default()).unwrap();
        let rec = c.db.get_flare(&r.flare_id).unwrap();
        assert_eq!(rec.node.as_deref(), Some("node-1"));
        let d = rec.placement.expect("decision recorded");
        assert_eq!(d.get("winner").unwrap().as_str(), Some("node-1"));
        let cands = d.get("candidates").unwrap().as_arr().unwrap();
        assert_eq!(cands.len(), 2);
        // node-0 (1×4) could never host 8 workers: reject reason recorded.
        let n0 = cands
            .iter()
            .find(|x| x.get("node").unwrap().as_str() == Some("node-0"))
            .unwrap();
        assert!(n0.get("reject").is_some());
        // Admission bounds against the largest single node, not the sum.
        let err = c
            .flare("place", vec![Json::Null; 10], &FlareOptions::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("cluster has 8"), "{err}");
    }

    #[test]
    fn unknown_definition_rejected() {
        let c = Controller::test_platform(1, 4, 1e-6);
        assert!(c.flare("ghost", vec![Json::Null], &FlareOptions::default()).is_err());
    }

    #[test]
    fn tenant_and_priority_recorded_and_validated() {
        register_echo();
        let c = Controller::test_platform(1, 8, 1e-6);
        c.deploy("tp", "ctrl-echo", BurstConfig::default()).unwrap();
        let opts = FlareOptions {
            tenant: Some("acme".into()),
            priority: Some("high".into()),
            ..Default::default()
        };
        let r = c.flare("tp", vec![Json::Null; 2], &opts).unwrap();
        let rec = c.db.get_flare(&r.flare_id).unwrap();
        assert_eq!(rec.tenant, "acme");
        assert_eq!(rec.priority, crate::platform::queue::Priority::High);
        // A bogus priority is an admission error, named in the message.
        let bad = FlareOptions { priority: Some("urgent".into()), ..Default::default() };
        let err = c.flare("tp", vec![Json::Null; 2], &bad).unwrap_err().to_string();
        assert!(err.contains("unknown priority 'urgent'"), "{err}");
    }

    #[test]
    fn preemption_and_deadline_options_parse_and_record() {
        register_echo();
        let c = Controller::test_platform(1, 8, 1e-6);
        c.deploy("pd", "ctrl-echo", BurstConfig::default()).unwrap();
        let opts = FlareOptions::from_json(
            &Json::parse(r#"{"preemptible":false,"deadline_ms":60000}"#).unwrap(),
        );
        assert_eq!(opts.preemptible, Some(false));
        assert_eq!(opts.deadline_ms, Some(60_000));
        let r = c.flare("pd", vec![Json::Null; 2], &opts).unwrap();
        let rec = c.db.get_flare(&r.flare_id).unwrap();
        assert_eq!(rec.deadline_ms, Some(60_000));
        assert_eq!(rec.preempt_count, 0);
        // Never preempted, never expired on this idle cluster.
        assert_eq!(c.preemptions(), 0);
        assert_eq!(c.expirations(), 0);
    }

    #[test]
    fn cancel_unknown_flare_is_not_found() {
        let c = Controller::test_platform(1, 4, 1e-6);
        assert_eq!(c.cancel_flare("ghost-1"), Err(CancelError::NotFound));
    }

    #[test]
    fn cancel_after_terminal_is_a_conflict() {
        register_echo();
        let c = Controller::test_platform(1, 8, 1e-6);
        c.deploy("done", "ctrl-echo", BurstConfig::default()).unwrap();
        let r = c.flare("done", vec![Json::Null; 2], &FlareOptions::default()).unwrap();
        assert_eq!(
            c.cancel_flare(&r.flare_id),
            Err(CancelError::AlreadyTerminal(FlareStatus::Completed))
        );
        // The record still says completed — cancel did not clobber it.
        assert_eq!(c.flare_status(&r.flare_id), Some(FlareStatus::Completed));
    }
}
