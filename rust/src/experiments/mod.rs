//! Experiment drivers: one per table/figure of the paper's evaluation
//! (§5, DESIGN.md §5). Each driver runs the workload, prints the same
//! rows/series the paper reports, and returns its data for tests and
//! EXPERIMENTS.md.
//!
//! `quick: true` shrinks payloads/sizes for CI; the shapes (who wins, by
//! what factor) must hold in both modes — tests assert them in quick mode.

pub mod fig1_coldstart;
pub mod fig5_startup;
pub mod fig6_simultaneity;
pub mod fig7_dataloading;
pub mod fig8_backends;
pub mod fig9_collectives;
pub mod fig10_pagerank;
pub mod fig11_terasort;
pub mod table1_clusters;
pub mod table3_gridsearch;

use std::sync::Arc;

use crate::apps::{self, AppEnv};
use crate::cluster::costmodel::CostModel;
use crate::cluster::netmodel::NetParams;
use crate::cluster::ClusterSpec;
use crate::platform::Controller;
use crate::runtime::engine::global_pool;
use crate::storage::ObjectStore;

/// Build a platform + app environment for an experiment: `invokers` ×
/// `vcpus` cluster, network model compressed by `time_scale`, apps
/// registered against a fresh object store.
pub fn platform(invokers: usize, vcpus: usize, time_scale: f64) -> (Arc<Controller>, AppEnv) {
    let net = NetParams::scaled(time_scale);
    let controller = Controller::new(
        ClusterSpec::uniform(invokers, vcpus),
        CostModel::default(),
        net.clone(),
    );
    let env = AppEnv {
        store: ObjectStore::new(net),
        pool: global_pool().expect("artifacts missing — run `make artifacts`"),
    };
    apps::register_all(&env);
    (controller, env)
}

/// Run every experiment (CLI `burstctl experiment all`).
pub fn run_all(quick: bool) {
    table1_clusters::run(quick);
    fig1_coldstart::run(quick);
    fig5_startup::run(quick);
    fig6_simultaneity::run(quick);
    fig7_dataloading::run(quick);
    fig8_backends::run_chunk_size(quick);
    fig8_backends::run_scaling(quick);
    fig9_collectives::run(quick);
    table3_gridsearch::run(quick);
    fig10_pagerank::run(quick);
    fig11_terasort::run(quick);
}
