//! Integration tests for the flare scheduling pipeline: queueing under a
//! saturated pool, concurrent flares against one `InvokerPool`, backfill
//! semantics, capacity hygiene on worker failure, multi-tenant fairness
//! under saturation, priority placement, the cancellation kill path,
//! preempt-and-requeue (saturation reclaim, the `preemptible = false`
//! opt-out, the preempt-count livelock guard, the cancel-beats-requeue
//! race), EDF ordering, queued-deadline expiry, and DAG workflows
//! (`after` edges: parent-output hand-off, the waiting-on-parents holding
//! area, and cancellation fan-out).
//! These use plain registered work functions (no app datasets), gated by
//! condvars so the tests control exactly when capacity frees.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::anyhow;
use burstc::platform::{
    register_work, BurstConfig, CancelError, CancelOutcome, Controller, FlareOptions,
    FlareStatus, WorkFn,
};
use burstc::util::json::Json;

/// A gate every worker of a flare blocks on until the test opens it.
#[derive(Default)]
struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn work(gate: &Arc<Gate>) -> WorkFn {
        let gate = gate.clone();
        Arc::new(move |_p, _ctx| {
            let deadline = Instant::now() + Duration::from_secs(20);
            let mut open = gate.open.lock().unwrap();
            while !*open {
                if Instant::now() >= deadline {
                    return Err(anyhow!("gate never opened (test hang guard)"));
                }
                let (guard, _) = gate
                    .cv
                    .wait_timeout(open, Duration::from_millis(100))
                    .unwrap();
                open = guard;
            }
            Ok(Json::Null)
        })
    }

    fn open(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }
}

impl Gate {
    /// Like [`Gate::work`], but with a cooperative cancellation point in
    /// the poll loop: a preempt (or cancel) unwinds the worker instead of
    /// parking it until the gate opens.
    fn preemptible_work(gate: &Arc<Gate>) -> WorkFn {
        let gate = gate.clone();
        Arc::new(move |_p, ctx: &burstc::bcm::BurstContext| {
            let deadline = Instant::now() + Duration::from_secs(20);
            loop {
                if *gate.open.lock().unwrap() {
                    return Ok(Json::Null);
                }
                ctx.check_cancel()?;
                if Instant::now() >= deadline {
                    return Err(anyhow!("gate never opened (test hang guard)"));
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        })
    }
}

fn noop() -> WorkFn {
    Arc::new(|_p, _ctx| Ok(Json::Null))
}

/// Poll an arbitrary condition until it holds (or the timeout lapses).
fn wait_until(mut pred: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + Duration::from_secs(10);
    while Instant::now() < deadline {
        if pred() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    false
}

fn hetero() -> BurstConfig {
    BurstConfig { strategy: "heterogeneous".into(), ..Default::default() }
}

/// Poll the db-backed status until it matches (or the timeout lapses).
fn wait_status(c: &Controller, id: &str, want: FlareStatus) -> bool {
    let deadline = Instant::now() + Duration::from_secs(10);
    while Instant::now() < deadline {
        if c.flare_status(id) == Some(want) {
            return true;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    false
}

/// Acceptance: a flare submitted while the pool is saturated returns an id
/// immediately, is observable as `queued`, and completes once capacity
/// frees.
#[test]
fn saturated_pool_queues_then_runs_second_flare() {
    let gate = Arc::new(Gate::default());
    register_work("sched-gated", Gate::work(&gate));
    let c = Controller::test_platform(1, 8, 1e-6);
    c.deploy("sat", "sched-gated", hetero()).unwrap();

    // Flare A fills the single invoker and parks on the gate.
    let ha = c.submit_flare("sat", vec![Json::Null; 8], &FlareOptions::default()).unwrap();
    assert!(wait_status(&c, &ha.flare_id, FlareStatus::Running));
    assert_eq!(c.pool.free_vcpus(), vec![0]);

    // Flare B: submit returns immediately with an id; it must sit queued.
    let hb = c.submit_flare("sat", vec![Json::Null; 4], &FlareOptions::default()).unwrap();
    assert_eq!(c.flare_status(&hb.flare_id), Some(FlareStatus::Queued));
    std::thread::sleep(Duration::from_millis(100));
    assert_eq!(c.flare_status(&hb.flare_id), Some(FlareStatus::Queued));
    assert!(!hb.is_finished());

    // Capacity frees → B is placed and completes.
    gate.open();
    let ra = ha.wait().unwrap();
    let rb = hb.wait().unwrap();
    assert_eq!(ra.outputs.len(), 8);
    assert_eq!(rb.outputs.len(), 4);
    // B measurably waited in the queue, and the wait is on its timeline.
    assert!(rb.queue_wait_s >= 0.1, "queue wait {}", rb.queue_wait_s);
    let queue_spans = rb.timeline.phase_durations(burstc::metrics::Phase::Queue);
    assert_eq!(queue_spans.len(), 4);
    assert!(queue_spans.iter().all(|&d| d >= 0.1));
    assert_eq!(c.flare_status(&ra.flare_id), Some(FlareStatus::Completed));
    assert_eq!(c.flare_status(&rb.flare_id), Some(FlareStatus::Completed));
    assert_eq!(c.pool.free_vcpus(), vec![8]);
}

/// Satellite: N threads submitting flares against a small pool — all
/// complete, and capacity is fully released at the end.
#[test]
fn concurrent_flares_all_complete_and_release_capacity() {
    register_work("sched-noop", noop());
    let c = Controller::test_platform(2, 8, 1e-6);
    c.deploy("cc", "sched-noop", hetero()).unwrap();
    // 8 threads × 4 workers = 32 vCPU-demand against 16 vCPUs: queueing is
    // forced, every flare must still complete exactly once.
    let ids = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for _ in 0..8 {
            s.spawn(|| {
                let r = c
                    .flare("cc", vec![Json::Null; 4], &FlareOptions::default())
                    .unwrap();
                assert_eq!(r.outputs.len(), 4);
                ids.lock().unwrap().push(r.flare_id);
            });
        }
    });
    let mut ids = ids.into_inner().unwrap();
    assert_eq!(ids.len(), 8);
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), 8, "flare ids must be unique");
    assert_eq!(c.pool.free_vcpus(), vec![8, 8]);
}

/// Satellite: a worker failure fails the flare but leaks no reservation.
#[test]
fn worker_failure_releases_capacity_and_marks_failed() {
    let failing: WorkFn = Arc::new(|_p, ctx| {
        if ctx.worker_id == 1 {
            Err(anyhow!("injected worker fault"))
        } else {
            Ok(Json::Null)
        }
    });
    register_work("sched-faulty", failing);
    register_work("sched-healthy", noop());
    let c = Controller::test_platform(1, 4, 1e-6);
    c.deploy("bad", "sched-faulty", hetero()).unwrap();
    c.deploy("good", "sched-healthy", hetero()).unwrap();

    let h = c.submit_flare("bad", vec![Json::Null; 4], &FlareOptions::default()).unwrap();
    let id = h.flare_id.clone();
    let err = h.wait().unwrap_err().to_string();
    assert!(err.contains("worker 1"), "{err}");
    let rec = c.db.get_flare(&id).unwrap();
    assert_eq!(rec.status, FlareStatus::Failed);
    assert!(rec.error.unwrap().contains("worker 1"));

    // Nothing leaked: the full pool is immediately usable again.
    assert_eq!(c.pool.free_vcpus(), vec![4]);
    let r = c.flare("good", vec![Json::Null; 4], &FlareOptions::default()).unwrap();
    assert_eq!(r.outputs.len(), 4);
}

/// Satellite: backfill lets a fitting flare pass a blocked larger one, and
/// the blocked one still runs once capacity frees (no starvation).
#[test]
fn backfill_passes_blocked_flare_without_starving_it() {
    let gate_a = Arc::new(Gate::default());
    let gate_c = Arc::new(Gate::default());
    register_work("sched-gate-a", Gate::work(&gate_a));
    register_work("sched-gate-c", Gate::work(&gate_c));
    register_work("sched-open", noop());
    let c = Controller::test_platform(1, 8, 1e-6);
    c.deploy("a", "sched-gate-a", hetero()).unwrap();
    c.deploy("b", "sched-open", hetero()).unwrap();
    c.deploy("cf", "sched-gate-c", hetero()).unwrap();

    // A occupies 6 of 8 vCPUs and parks.
    let ha = c.submit_flare("a", vec![Json::Null; 6], &FlareOptions::default()).unwrap();
    assert!(wait_status(&c, &ha.flare_id, FlareStatus::Running));

    // B needs the whole machine: admitted (≤ total capacity) but queued.
    let hb = c.submit_flare("b", vec![Json::Null; 8], &FlareOptions::default()).unwrap();
    assert_eq!(c.flare_status(&hb.flare_id), Some(FlareStatus::Queued));

    // C fits in the 2 free vCPUs: backfill runs it past blocked B.
    let hc = c.submit_flare("cf", vec![Json::Null; 2], &FlareOptions::default()).unwrap();
    assert!(wait_status(&c, &hc.flare_id, FlareStatus::Running));
    assert_eq!(c.flare_status(&hb.flare_id), Some(FlareStatus::Queued));

    // C finishes; B still blocked on A's 6 vCPUs.
    gate_c.open();
    hc.wait().unwrap();
    assert_eq!(c.flare_status(&hb.flare_id), Some(FlareStatus::Queued));

    // A finishes → the blocked flare finally runs to completion.
    gate_a.open();
    ha.wait().unwrap();
    let rb = hb.wait().unwrap();
    assert_eq!(rb.outputs.len(), 8);
    assert!(rb.queue_wait_s > 0.0);
    assert_eq!(c.pool.free_vcpus(), vec![8]);
}

fn opts_for(tenant: &str, priority: &str) -> FlareOptions {
    FlareOptions {
        tenant: Some(tenant.to_string()),
        priority: Some(priority.to_string()),
        ..Default::default()
    }
}

/// Tentpole acceptance: a heavy tenant flooding a saturated cluster cannot
/// starve a light one — the weighted-fair pick interleaves their
/// placements, so the light tenant finishes long before the heavy backlog
/// drains.
#[test]
fn heavy_tenant_cannot_starve_light_tenant_under_saturation() {
    register_work(
        "sched-sleep",
        Arc::new(|_p, _ctx| {
            std::thread::sleep(Duration::from_millis(20));
            Ok(Json::Null)
        }),
    );
    let c = Controller::test_platform(1, 4, 1e-6);
    c.deploy("fair", "sched-sleep", hetero()).unwrap();

    // Every flare needs the whole machine: placements are strictly serial,
    // so completion order is placement order.
    let heavy: Vec<_> = (0..10)
        .map(|_| {
            c.submit_flare("fair", vec![Json::Null; 4], &opts_for("heavy", "normal"))
                .unwrap()
        })
        .collect();
    let light: Vec<_> = (0..3)
        .map(|_| {
            c.submit_flare("fair", vec![Json::Null; 4], &opts_for("light", "normal"))
                .unwrap()
        })
        .collect();

    let order = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for h in heavy {
            let order = &order;
            s.spawn(move || {
                let id = h.flare_id.clone();
                h.wait().unwrap();
                order.lock().unwrap().push((id, "heavy"));
            });
        }
        for h in light {
            let order = &order;
            s.spawn(move || {
                let id = h.flare_id.clone();
                h.wait().unwrap();
                order.lock().unwrap().push((id, "light"));
            });
        }
    });
    let order = order.into_inner().unwrap();
    assert_eq!(order.len(), 13);
    let last_light = order
        .iter()
        .rposition(|(_, t)| *t == "light")
        .expect("light flares completed");
    let heavy_before = order[..last_light].iter().filter(|(_, t)| *t == "heavy").count();
    // Fair interleave places the 3rd light flare by round ~6 (3 heavy
    // ahead of it); FIFO starvation would put all 10 heavy first. The
    // margin tolerates completion-delivery jitter.
    assert!(
        heavy_before <= 6,
        "light tenant starved: {heavy_before} heavy flares finished first ({order:?})"
    );
    assert_eq!(c.pool.free_vcpus(), vec![4]);
}

/// Priorities order placements within a tenant: a high-priority flare
/// submitted last is placed first once capacity frees (no inversion by
/// earlier low-priority arrivals).
#[test]
fn high_priority_flare_placed_before_earlier_low_priority_ones() {
    let gate = Arc::new(Gate::default());
    register_work("sched-gate-prio", Gate::work(&gate));
    register_work(
        "sched-sleep-prio",
        Arc::new(|_p, _ctx| {
            std::thread::sleep(Duration::from_millis(15));
            Ok(Json::Null)
        }),
    );
    let c = Controller::test_platform(1, 4, 1e-6);
    c.deploy("hold", "sched-gate-prio", hetero()).unwrap();
    c.deploy("prio", "sched-sleep-prio", hetero()).unwrap();

    // Saturate, then queue low → normal → high in arrival order.
    let ha = c.submit_flare("hold", vec![Json::Null; 4], &FlareOptions::default()).unwrap();
    assert!(wait_status(&c, &ha.flare_id, FlareStatus::Running));
    let hb = c.submit_flare("prio", vec![Json::Null; 4], &opts_for("t", "low")).unwrap();
    let hc = c.submit_flare("prio", vec![Json::Null; 4], &opts_for("t", "normal")).unwrap();
    let hd = c.submit_flare("prio", vec![Json::Null; 4], &opts_for("t", "high")).unwrap();

    gate.open();
    ha.wait().unwrap();
    let rb = hb.wait().unwrap();
    let rc = hc.wait().unwrap();
    let rd = hd.wait().unwrap();
    // Serial placements 15 ms apart: queue waits order the placements as
    // high < normal < low despite the reverse arrival order.
    assert!(
        rd.queue_wait_s < rc.queue_wait_s && rc.queue_wait_s < rb.queue_wait_s,
        "expected high < normal < low, got high={} normal={} low={}",
        rd.queue_wait_s,
        rc.queue_wait_s,
        rb.queue_wait_s
    );
    assert_eq!(c.pool.free_vcpus(), vec![4]);
}

/// Cancel-while-queued race: the flare is pulled out before placement, its
/// waiter fails fast, and no capacity is ever consumed for it.
#[test]
fn cancel_while_queued_fails_fast_and_consumes_nothing() {
    let gate = Arc::new(Gate::default());
    register_work("sched-gate-cq", Gate::work(&gate));
    let c = Controller::test_platform(1, 4, 1e-6);
    c.deploy("cq", "sched-gate-cq", hetero()).unwrap();

    let ha = c.submit_flare("cq", vec![Json::Null; 4], &FlareOptions::default()).unwrap();
    assert!(wait_status(&c, &ha.flare_id, FlareStatus::Running));
    let hb = c.submit_flare("cq", vec![Json::Null; 4], &FlareOptions::default()).unwrap();
    assert_eq!(c.flare_status(&hb.flare_id), Some(FlareStatus::Queued));

    let id_b = hb.flare_id.clone();
    assert_eq!(c.cancel_flare(&id_b), Ok(CancelOutcome::CancelledQueued));
    // The waiter fails fast — long before the gate opens.
    let err = hb.wait().unwrap_err().to_string();
    assert!(err.contains("cancelled"), "{err}");
    assert_eq!(c.flare_status(&id_b), Some(FlareStatus::Cancelled));
    assert_eq!(c.queued_flares(), 0);

    gate.open();
    ha.wait().unwrap();
    assert_eq!(c.pool.free_vcpus(), vec![4]);
}

/// Cancel-while-running: the token trips, workers observe it at their next
/// cancellation point, the reservation is released *without waiting for
/// the work to finish*, and a queued flare immediately consumes the freed
/// capacity.
#[test]
fn cancel_while_running_releases_capacity_to_queued_flares() {
    // Work that never finishes on its own: it parks until cancelled.
    register_work(
        "sched-cancellable",
        Arc::new(|_p, ctx: &burstc::bcm::BurstContext| {
            let deadline = Instant::now() + Duration::from_secs(20);
            while !ctx.cancelled() {
                if Instant::now() >= deadline {
                    return Err(anyhow!("never cancelled (test hang guard)"));
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            ctx.check_cancel()?;
            Ok(Json::Null)
        }),
    );
    register_work("sched-after", noop());
    let c = Controller::test_platform(1, 4, 1e-6);
    c.deploy("victim", "sched-cancellable", hetero()).unwrap();
    c.deploy("next", "sched-after", hetero()).unwrap();

    let ha = c.submit_flare("victim", vec![Json::Null; 4], &FlareOptions::default()).unwrap();
    assert!(wait_status(&c, &ha.flare_id, FlareStatus::Running));
    let hb = c.submit_flare("next", vec![Json::Null; 4], &FlareOptions::default()).unwrap();
    assert_eq!(c.flare_status(&hb.flare_id), Some(FlareStatus::Queued));

    let id_a = ha.flare_id.clone();
    assert_eq!(c.cancel_flare(&id_a), Ok(CancelOutcome::CancellingRunning));
    let err = ha.wait().unwrap_err().to_string();
    assert!(err.contains("cancelled"), "{err}");
    assert_eq!(c.flare_status(&id_a), Some(FlareStatus::Cancelled));

    // The freed reservation goes straight to the queued flare.
    let rb = hb.wait().unwrap();
    assert_eq!(rb.outputs.len(), 4);
    assert_eq!(c.flare_status(&rb.flare_id), Some(FlareStatus::Completed));
    assert_eq!(c.pool.free_vcpus(), vec![4]);
}

/// Cancel-after-terminal race: cancelling a flare that already finished is
/// a clean conflict and does not disturb the stored record.
#[test]
fn cancel_after_terminal_is_clean_conflict() {
    register_work("sched-done", noop());
    let c = Controller::test_platform(1, 4, 1e-6);
    c.deploy("done", "sched-done", hetero()).unwrap();
    let r = c.flare("done", vec![Json::Null; 2], &FlareOptions::default()).unwrap();
    assert_eq!(
        c.cancel_flare(&r.flare_id),
        Err(CancelError::AlreadyTerminal(FlareStatus::Completed))
    );
    assert_eq!(c.cancel_flare("no-such-flare"), Err(CancelError::NotFound));
    assert_eq!(c.flare_status(&r.flare_id), Some(FlareStatus::Completed));
}

/// Tentpole acceptance: a saturated cluster of low-priority flares yields
/// to a newly submitted high flare via preemption — the high flare runs
/// without waiting for any victim's natural completion (the gate stays
/// closed throughout), the victim is requeued with its preemption counted,
/// and everything reaches a clean terminal state with capacity released.
#[test]
fn preemption_reclaims_saturated_cluster_for_high_flare() {
    let gate = Arc::new(Gate::default());
    register_work("sched-victim", Gate::preemptible_work(&gate));
    register_work("sched-urgent", noop());
    let c = Controller::test_platform(1, 4, 1e-6);
    c.deploy("victim", "sched-victim", hetero()).unwrap();
    c.deploy("urgent", "sched-urgent", hetero()).unwrap();

    // A low-priority flare saturates the cluster and parks on the gate.
    let hv = c.submit_flare("victim", vec![Json::Null; 4], &opts_for("bulk", "low")).unwrap();
    assert!(wait_status(&c, &hv.flare_id, FlareStatus::Running));
    assert_eq!(c.pool.free_vcpus(), vec![0]);

    // The high flare completes while the victim's gate never opened: its
    // capacity can only have come from preemption.
    let hu = c.submit_flare("urgent", vec![Json::Null; 4], &opts_for("urgent", "high")).unwrap();
    let ru = hu.wait().unwrap();
    assert_eq!(ru.outputs.len(), 4);
    assert!(c.preemptions() >= 1, "the scheduler never preempted");

    // The victim cycled running → queued (preempt_count = 1, visible in
    // its record) and is re-placed once the high flare frees capacity.
    let preempted_once = || c.db.get_flare(&hv.flare_id).is_some_and(|r| r.preempt_count == 1);
    assert!(wait_until(preempted_once));
    assert!(wait_status(&c, &hv.flare_id, FlareStatus::Running));

    // Open the gate: the requeued victim completes normally.
    gate.open();
    let rv = hv.wait().unwrap();
    assert_eq!(rv.outputs.len(), 4);
    assert_eq!(c.flare_status(&rv.flare_id), Some(FlareStatus::Completed));
    assert_eq!(c.pool.free_vcpus(), vec![4]);
}

/// Tentpole acceptance (ISSUE 5): a preempted flare *resumes* from its
/// workers' last checkpoints instead of restarting `work` from scratch.
/// The executed-iteration counter proves it: each of the 4 workers runs
/// its 5 iterations exactly once across both runs (a from-scratch re-run
/// would re-execute the pre-preemption iterations), and `resume_count`
/// lands in the record and its JSON (the `GET /v1/flares/<id>` payload).
#[test]
fn preempted_flare_resumes_from_checkpoint_not_scratch() {
    use std::sync::atomic::{AtomicU64, Ordering};
    const ITERS: u64 = 5;
    const PARK_AT: u64 = 2;
    let gate = Arc::new(Gate::default());
    let executed = Arc::new(AtomicU64::new(0));
    let restored_max = Arc::new(AtomicU64::new(0));
    let work: WorkFn = {
        let gate = gate.clone();
        let executed = executed.clone();
        let restored_max = restored_max.clone();
        Arc::new(move |_p, ctx: &burstc::bcm::BurstContext| {
            let start = match ctx.restore() {
                Some(b) if b.len() == 8 => {
                    u64::from_le_bytes(b[..8].try_into().unwrap())
                }
                _ => 0,
            };
            restored_max.fetch_max(start, Ordering::Relaxed);
            for it in start..ITERS {
                if it == PARK_AT {
                    // Park (cancellable) until the test opens the gate:
                    // the preempt trips here, with iterations 0..PARK_AT
                    // already checkpointed.
                    let deadline = Instant::now() + Duration::from_secs(20);
                    while !*gate.open.lock().unwrap() {
                        ctx.check_cancel()?;
                        if Instant::now() >= deadline {
                            return Err(anyhow!("gate never opened (hang guard)"));
                        }
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
                ctx.checkpoint((it + 1).to_le_bytes().to_vec());
                executed.fetch_add(1, Ordering::Relaxed);
            }
            Ok(Json::Null)
        })
    };
    register_work("sched-ckpt-victim", work);
    register_work("sched-ckpt-urgent", noop());
    let c = Controller::test_platform(1, 4, 1e-6);
    c.deploy("ckvic", "sched-ckpt-victim", hetero()).unwrap();
    c.deploy("ckurg", "sched-ckpt-urgent", hetero()).unwrap();

    // The victim saturates the cluster, checkpoints PARK_AT iterations per
    // worker, and parks.
    let hv = c
        .submit_flare("ckvic", vec![Json::Null; 4], &opts_for("bulk", "low"))
        .unwrap();
    let hv_id = hv.flare_id.clone();
    assert!(wait_status(&c, &hv_id, FlareStatus::Running));
    assert!(wait_until(|| executed.load(Ordering::Relaxed) == 4 * PARK_AT));

    // A high flare preempts it; the parked workers unwind at the trip.
    let hu = c
        .submit_flare("ckurg", vec![Json::Null; 4], &opts_for("urgent", "high"))
        .unwrap();
    hu.wait().unwrap();
    assert!(wait_until(|| c.db.get_flare(&hv_id).is_some_and(|r| r.preempt_count == 1)));
    // The checkpoints survived the preempt-requeue cycle.
    assert_eq!(c.db.checkpoints_for(&hv_id).by_worker.len(), 4);

    // Let the resumed run proceed: it must pick up at PARK_AT, not 0.
    gate.open();
    hv.wait().unwrap();
    assert_eq!(
        executed.load(Ordering::Relaxed),
        4 * ITERS,
        "every iteration ran exactly once across both runs — \
         checkpointed iterations were not re-executed"
    );
    assert_eq!(
        restored_max.load(Ordering::Relaxed),
        PARK_AT,
        "the resumed run restored the last checkpoint"
    );
    let rec = c.db.get_flare(&hv_id).unwrap();
    assert_eq!(rec.preempt_count, 1);
    assert_eq!(rec.resume_count, 1);
    assert_eq!(rec.to_json().get("resume_count").unwrap().as_usize(), Some(1));
    assert_eq!(c.resumes(), 1);
    // Terminal completion discarded the checkpoints.
    assert!(c.db.checkpoints_for(&hv_id).by_worker.is_empty());
    assert_eq!(c.pool.free_vcpus(), vec![4]);
}

/// `preemptible = false` opts a flare out: the high flare waits for the
/// victim's natural completion, and nothing is ever preempted.
#[test]
fn non_preemptible_flares_are_never_preempted() {
    let gate = Arc::new(Gate::default());
    register_work("sched-nopre", Gate::preemptible_work(&gate));
    register_work("sched-urgent2", noop());
    let c = Controller::test_platform(1, 4, 1e-6);
    c.deploy("nopre", "sched-nopre", hetero()).unwrap();
    c.deploy("urgent2", "sched-urgent2", hetero()).unwrap();

    let mut opts = opts_for("bulk", "low");
    opts.preemptible = Some(false);
    let hv = c.submit_flare("nopre", vec![Json::Null; 4], &opts).unwrap();
    assert!(wait_status(&c, &hv.flare_id, FlareStatus::Running));

    let hu = c.submit_flare("urgent2", vec![Json::Null; 4], &opts_for("urgent", "high")).unwrap();
    // Give the scheduler ample passes: the high flare must stay queued and
    // the opted-out victim must keep running, unpreempted.
    std::thread::sleep(Duration::from_millis(200));
    assert_eq!(c.flare_status(&hu.flare_id), Some(FlareStatus::Queued));
    assert_eq!(c.flare_status(&hv.flare_id), Some(FlareStatus::Running));
    assert_eq!(c.preemptions(), 0);

    // Only natural completion frees the capacity.
    gate.open();
    hv.wait().unwrap();
    hu.wait().unwrap();
    assert_eq!(c.db.get_flare(&hv.flare_id).unwrap().preempt_count, 0);
    assert_eq!(c.pool.free_vcpus(), vec![4]);
}

/// The preempt-count livelock guard: once a victim has been preempted
/// `max_preempts` times it stops being selectable, so a stream of high
/// flares cannot bounce it forever.
#[test]
fn preempt_count_guard_prevents_livelock() {
    let gate = Arc::new(Gate::default());
    register_work("sched-bounce", Gate::preemptible_work(&gate));
    register_work("sched-hi-seq", noop());
    let c = Controller::test_platform(1, 4, 1e-6);
    c.set_preemption_policy(true, 1);
    c.deploy("bounce", "sched-bounce", hetero()).unwrap();
    c.deploy("hiseq", "sched-hi-seq", hetero()).unwrap();

    let hv = c.submit_flare("bounce", vec![Json::Null; 4], &opts_for("bulk", "low")).unwrap();
    assert!(wait_status(&c, &hv.flare_id, FlareStatus::Running));

    // First high flare: preempts the victim (its one allowed preemption).
    let h1 = c.submit_flare("hiseq", vec![Json::Null; 4], &opts_for("urgent", "high")).unwrap();
    h1.wait().unwrap();
    let preempted_once = || c.db.get_flare(&hv.flare_id).is_some_and(|r| r.preempt_count == 1);
    assert!(wait_until(preempted_once));
    // The victim is re-placed and parks again (gate still closed).
    assert!(wait_status(&c, &hv.flare_id, FlareStatus::Running));

    // Second high flare: the victim is at the cap — no further preemption.
    let h2 = c.submit_flare("hiseq", vec![Json::Null; 4], &opts_for("urgent", "high")).unwrap();
    std::thread::sleep(Duration::from_millis(200));
    assert_eq!(c.flare_status(&h2.flare_id), Some(FlareStatus::Queued));
    assert_eq!(c.flare_status(&hv.flare_id), Some(FlareStatus::Running));
    assert_eq!(c.preemptions(), 1, "guard must stop a second preemption");

    gate.open();
    let rv = hv.wait().unwrap();
    assert_eq!(rv.outputs.len(), 4);
    h2.wait().unwrap();
    assert_eq!(c.db.get_flare(&rv.flare_id).unwrap().preempt_count, 1);
    assert_eq!(c.pool.free_vcpus(), vec![4]);
}

/// Satellite bugfix: a user cancel racing the preempt-requeue window must
/// win — the victim ends terminal `Cancelled` and is never resurrected,
/// whichever side of the requeue the cancel lands on.
#[test]
fn cancel_beats_preempt_requeue_race() {
    let gate = Arc::new(Gate::default());
    register_work("sched-race", Gate::preemptible_work(&gate));
    register_work("sched-hi-race", noop());
    let c = Controller::test_platform(1, 4, 1e-6);
    c.deploy("race", "sched-race", hetero()).unwrap();
    c.deploy("hirace", "sched-hi-race", hetero()).unwrap();

    let hv = c.submit_flare("race", vec![Json::Null; 4], &opts_for("bulk", "low")).unwrap();
    assert!(wait_status(&c, &hv.flare_id, FlareStatus::Running));

    // Trigger preemption and immediately fire the user cancel into the
    // preempt → unwind → requeue window.
    let hu = c.submit_flare("hirace", vec![Json::Null; 4], &opts_for("urgent", "high")).unwrap();
    let id_v = hv.flare_id.clone();
    c.cancel_flare(&id_v).expect("victim not terminal yet");

    // The waiter fails, the status is terminal Cancelled, and it stays
    // that way — no resurrection from a pending requeue.
    let err = hv.wait().unwrap_err().to_string();
    assert!(err.contains("cancelled"), "{err}");
    assert!(wait_until(|| c.flare_status(&id_v) == Some(FlareStatus::Cancelled)));
    std::thread::sleep(Duration::from_millis(100));
    assert_eq!(c.flare_status(&id_v), Some(FlareStatus::Cancelled));
    assert_eq!(c.queued_flares(), 0, "cancelled victim must not re-queue");

    hu.wait().unwrap();
    gate.open();
    assert_eq!(c.pool.free_vcpus(), vec![4]);
}

/// Deadline-aware placement: within one priority class, EDF orders the
/// queue — the soonest deadline is placed first, deadline-less flares
/// last, despite the reverse arrival order.
#[test]
fn edf_orders_same_class_flares_by_deadline() {
    let gate = Arc::new(Gate::default());
    register_work("sched-gate-edf", Gate::work(&gate));
    register_work(
        "sched-sleep-edf",
        Arc::new(|_p, _ctx| {
            std::thread::sleep(Duration::from_millis(15));
            Ok(Json::Null)
        }),
    );
    let c = Controller::test_platform(1, 4, 1e-6);
    c.deploy("hold", "sched-gate-edf", hetero()).unwrap();
    c.deploy("edf", "sched-sleep-edf", hetero()).unwrap();

    // Saturate, then queue no-deadline → late → soon in arrival order.
    let ha = c.submit_flare("hold", vec![Json::Null; 4], &FlareOptions::default()).unwrap();
    assert!(wait_status(&c, &ha.flare_id, FlareStatus::Running));
    let mk = |deadline_ms: Option<u64>| FlareOptions {
        deadline_ms,
        ..opts_for("t", "normal")
    };
    let h_none = c.submit_flare("edf", vec![Json::Null; 4], &mk(None)).unwrap();
    let h_late = c.submit_flare("edf", vec![Json::Null; 4], &mk(Some(60_000))).unwrap();
    let h_soon = c.submit_flare("edf", vec![Json::Null; 4], &mk(Some(30_000))).unwrap();

    gate.open();
    ha.wait().unwrap();
    let r_none = h_none.wait().unwrap();
    let r_late = h_late.wait().unwrap();
    let r_soon = h_soon.wait().unwrap();
    // Serial placements 15 ms apart: queue waits order as soon < late <
    // none despite arrival order none < late < soon.
    assert!(
        r_soon.queue_wait_s < r_late.queue_wait_s
            && r_late.queue_wait_s < r_none.queue_wait_s,
        "expected EDF order, got soon={} late={} none={}",
        r_soon.queue_wait_s,
        r_late.queue_wait_s,
        r_none.queue_wait_s
    );
    assert_eq!(c.pool.free_vcpus(), vec![4]);
}

/// A flare whose deadline lapses while queued fails fast with the distinct
/// terminal `Expired` status, without ever being placed.
#[test]
fn queued_flare_past_deadline_expires() {
    let gate = Arc::new(Gate::default());
    register_work("sched-gate-exp", Gate::work(&gate));
    let c = Controller::test_platform(1, 4, 1e-6);
    c.deploy("exp", "sched-gate-exp", hetero()).unwrap();

    let ha = c.submit_flare("exp", vec![Json::Null; 4], &FlareOptions::default()).unwrap();
    assert!(wait_status(&c, &ha.flare_id, FlareStatus::Running));

    // 50 ms of patience behind a gated flare: can only expire.
    let opts = FlareOptions { deadline_ms: Some(50), ..opts_for("t", "normal") };
    let hb = c.submit_flare("exp", vec![Json::Null; 4], &opts).unwrap();
    assert!(wait_status(&c, &hb.flare_id, FlareStatus::Expired));
    let err = hb.wait().unwrap_err().to_string();
    assert!(err.contains("expired"), "{err}");
    assert_eq!(c.queued_flares(), 0);
    assert_eq!(c.expirations(), 1);
    let rec = c.db.get_flare(&hb.flare_id).unwrap();
    assert_eq!(rec.deadline_ms, Some(50));

    // The running flare is untouched by the expiry pass.
    gate.open();
    ha.wait().unwrap();
    assert_eq!(c.pool.free_vcpus(), vec![4]);
}

/// Hard tenant quotas at the platform level: a tenant at its cap cannot
/// place another flare even with plenty of free cluster capacity; the
/// wait is observable (`wait_reason: quota_blocked`) and in `/metrics`
/// terms the flare stays `queued`, not failed. Other tenants — including
/// backfill-sized flares — are unaffected.
#[test]
fn tenant_at_quota_waits_despite_free_capacity() {
    let gate = Arc::new(Gate::default());
    register_work("sched-gate-quota", Gate::work(&gate));
    register_work("sched-noop-quota", noop());
    // 2 invokers × 8 vCPUs: plenty of room beyond the quota.
    let c = Controller::test_platform(2, 8, 1e-6);
    c.deploy("gq", "sched-gate-quota", hetero()).unwrap();
    c.deploy("nq", "sched-noop-quota", hetero()).unwrap();
    c.set_tenant_quota("capped", Some(4));

    // The capped tenant fills its quota with a gated flare...
    let held = c
        .submit_flare("gq", vec![Json::Null; 4], &opts_for("capped", "normal"))
        .unwrap();
    assert!(wait_status(&c, &held.flare_id, FlareStatus::Running));
    // ...then a small flare of the same tenant must wait, with a reason,
    // even though 12 vCPUs are free (backfill must not bypass the quota).
    let blocked = c
        .submit_flare("nq", vec![Json::Null; 2], &opts_for("capped", "normal"))
        .unwrap();
    assert!(wait_until(|| {
        c.db.get_flare(&blocked.flare_id)
            .is_some_and(|r| r.wait_reason.as_deref() == Some("quota_blocked"))
    }));
    assert_eq!(c.flare_status(&blocked.flare_id), Some(FlareStatus::Queued));
    assert_eq!(c.quota_blocked_flares(), 1);

    // Another tenant sails past the quota-blocked wait.
    let free = c
        .submit_flare("nq", vec![Json::Null; 4], &opts_for("other", "normal"))
        .unwrap();
    free.wait().unwrap();

    // Releasing the held reservation frees the quota: the blocked flare
    // runs and its wait reason is cleared.
    let blocked_id = blocked.flare_id.clone();
    gate.open();
    held.wait().unwrap();
    blocked.wait().unwrap();
    let rec = c.db.get_flare(&blocked_id).unwrap();
    assert_eq!(rec.status, FlareStatus::Completed);
    assert_eq!(rec.wait_reason, None);
    assert_eq!(c.quota_blocked_flares(), 0);
    assert_eq!(c.pool.free_vcpus(), vec![8, 8]);
}

/// Raising (or clearing) a quota at runtime unblocks waiting flares on
/// the next scheduler pass — the knob is live, not submit-time-only.
#[test]
fn raising_quota_unblocks_waiting_flares() {
    let gate = Arc::new(Gate::default());
    register_work("sched-gate-quota2", Gate::work(&gate));
    register_work("sched-noop-quota2", noop());
    let c = Controller::test_platform(2, 8, 1e-6);
    c.deploy("gq2", "sched-gate-quota2", hetero()).unwrap();
    c.deploy("nq2", "sched-noop-quota2", hetero()).unwrap();
    c.set_tenant_quota("t", Some(4));

    let held = c
        .submit_flare("gq2", vec![Json::Null; 4], &opts_for("t", "normal"))
        .unwrap();
    assert!(wait_status(&c, &held.flare_id, FlareStatus::Running));
    let blocked = c
        .submit_flare("nq2", vec![Json::Null; 4], &opts_for("t", "normal"))
        .unwrap();
    assert!(wait_until(|| c.quota_blocked_flares() == 1));

    // Double the cap: the waiter no longer exceeds it and completes while
    // the first flare is *still* holding its original 4 vCPUs.
    c.set_tenant_quota("t", Some(8));
    blocked.wait().unwrap();
    assert_eq!(c.flare_status(&held.flare_id), Some(FlareStatus::Running));

    gate.open();
    held.wait().unwrap();
    // The policy is visible on the controller, usage drained to zero.
    let t = c
        .tenant_policies()
        .into_iter()
        .find(|p| p.tenant == "t")
        .expect("lane exists");
    assert_eq!(t.quota, Some(8));
    assert!(wait_until(|| {
        c.tenant_policies()
            .into_iter()
            .find(|p| p.tenant == "t")
            .is_some_and(|p| p.placed_vcpus == 0)
    }));
}

/// DAG happy path: a two-stage chain hands the parent's outputs to the
/// child through the backend — `parent_input(0)` returns exactly the
/// parent's output array, staged before any child worker starts.
#[test]
fn dag_chain_passes_parent_outputs_to_child() {
    register_work(
        "sched-dag-src",
        Arc::new(|_p, ctx: &burstc::bcm::BurstContext| {
            Ok(Json::Num((ctx.worker_id * 10) as f64))
        }),
    );
    register_work(
        "sched-dag-sum",
        Arc::new(|_p, ctx: &burstc::bcm::BurstContext| {
            let parents = ctx.parent_input(0)?;
            let total: f64 = parents
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(Json::as_f64)
                .sum();
            Ok(Json::Num(total))
        }),
    );
    let c = Controller::test_platform(1, 8, 1e-6);
    c.deploy("dag-src", "sched-dag-src", hetero()).unwrap();
    c.deploy("dag-sum", "sched-dag-sum", hetero()).unwrap();
    let a = c.flare("dag-src", vec![Json::Null; 4], &FlareOptions::default()).unwrap();
    let opts = FlareOptions { after: vec![a.flare_id.clone()], ..Default::default() };
    let b = c.flare("dag-sum", vec![Json::Null; 2], &opts).unwrap();
    // Every child worker read A's outputs 0 + 10 + 20 + 30.
    assert!(b.outputs.iter().all(|o| o.as_f64() == Some(60.0)), "{:?}", b.outputs);
}

/// A DAG child must hold in the waiting-on-parents area while its parent
/// runs — even with the cluster otherwise idle — and only enter the lanes
/// once the parent completes.
#[test]
fn dag_child_waits_for_running_parent_despite_free_capacity() {
    let gate = Arc::new(Gate::default());
    register_work("sched-dag-gated", Gate::work(&gate));
    register_work("sched-dag-noop", noop());
    let c = Controller::test_platform(1, 16, 1e-6);
    c.deploy("dag-gate", "sched-dag-gated", hetero()).unwrap();
    c.deploy("dag-wait", "sched-dag-noop", hetero()).unwrap();
    let a = c.submit_flare("dag-gate", vec![Json::Null; 2], &FlareOptions::default()).unwrap();
    assert!(wait_status(&c, &a.flare_id, FlareStatus::Running));
    let opts = FlareOptions { after: vec![a.flare_id.clone()], ..Default::default() };
    let b = c.submit_flare("dag-wait", vec![Json::Null; 2], &opts).unwrap();
    // 14 free vCPUs, but the child stays parked outside the DRR lanes.
    std::thread::sleep(Duration::from_millis(100));
    assert_eq!(c.flare_status(&b.flare_id), Some(FlareStatus::Queued));
    let rec = c.db.get_flare(&b.flare_id).unwrap();
    assert_eq!(rec.wait_reason.as_deref(), Some("waiting_on_parents"));
    gate.open();
    assert!(a.wait().is_ok());
    assert!(b.wait().is_ok());
}

/// Cancelling a parent fans out through every descendant: over a diamond
/// A → (B, C) → D, each of B, C, D lands in `ParentFailed` exactly once,
/// with an error naming the terminal parent one edge up, and no capacity
/// is left reserved.
#[test]
fn parent_cancellation_fans_out_to_every_descendant() {
    let gate = Arc::new(Gate::default());
    register_work("sched-dag-dia-gate", Gate::preemptible_work(&gate));
    register_work("sched-dag-dia-noop", noop());
    let c = Controller::test_platform(1, 16, 1e-6);
    c.deploy("dia-root", "sched-dag-dia-gate", hetero()).unwrap();
    c.deploy("dia-stage", "sched-dag-dia-noop", hetero()).unwrap();
    let a = c.submit_flare("dia-root", vec![Json::Null; 2], &FlareOptions::default()).unwrap();
    assert!(wait_status(&c, &a.flare_id, FlareStatus::Running));
    let after_a = FlareOptions { after: vec![a.flare_id.clone()], ..Default::default() };
    let b = c.submit_flare("dia-stage", vec![Json::Null; 2], &after_a).unwrap();
    let c2 = c.submit_flare("dia-stage", vec![Json::Null; 2], &after_a).unwrap();
    let after_bc = FlareOptions {
        after: vec![b.flare_id.clone(), c2.flare_id.clone()],
        ..Default::default()
    };
    let d = c.submit_flare("dia-stage", vec![Json::Null; 2], &after_bc).unwrap();

    c.cancel_flare(&a.flare_id).unwrap();
    assert!(wait_status(&c, &a.flare_id, FlareStatus::Cancelled));
    for id in [&b.flare_id, &c2.flare_id, &d.flare_id] {
        assert!(wait_status(&c, id, FlareStatus::ParentFailed), "descendant {id}");
    }
    // The middle tier blames the cancelled root; the sink blames a
    // parent-failed middle flare — one edge per level, no skipping.
    let err_b = c.db.get_flare(&b.flare_id).unwrap().error.unwrap();
    assert!(err_b.contains(&a.flare_id) && err_b.contains("cancelled"), "{err_b}");
    let err_d = c.db.get_flare(&d.flare_id).unwrap().error.unwrap();
    assert!(
        (err_d.contains(&b.flare_id) || err_d.contains(&c2.flare_id))
            && err_d.contains("parent_failed"),
        "{err_d}"
    );
    // Each handle observes the terminal error exactly once, and the
    // fan-out consumed no capacity.
    assert!(b.wait().is_err() && c2.wait().is_err() && d.wait().is_err());
    assert!(wait_until(|| c.pool.free_vcpus() == vec![16]));
}

/// DAG edges are validated at submit: naming a parent that was never
/// submitted is an error, not a flare that waits forever.
#[test]
fn unknown_parent_rejected_at_submit() {
    register_work("sched-dag-val", noop());
    let c = Controller::test_platform(1, 8, 1e-6);
    c.deploy("dag-val", "sched-dag-val", hetero()).unwrap();
    let opts = FlareOptions { after: vec!["no-such-flare".into()], ..Default::default() };
    let err = c.submit_flare("dag-val", vec![Json::Null; 2], &opts).unwrap_err();
    assert!(err.to_string().contains("unknown parent"), "{err}");
}
