//! Multi-node control plane: one controller, three invoker nodes.
//!
//! Demonstrates the two-level scheduling split (paper §4): the cluster side
//! scores every alive node per flare (fit, locality, fragmentation) against
//! its approximate free-vCPU view and records an explainable decision, while
//! each node's agent re-validates the placement against pool ground truth —
//! and may *refuse* it when the view was stale, triggering spillback onto
//! the next-best node. Finishes with the per-tenant billing export.
//!
//! Run: `cargo run --release --example multi_node`

use std::sync::Arc;
use std::time::{Duration, Instant};

use burstc::cluster::costmodel::CostModel;
use burstc::cluster::netmodel::NetParams;
use burstc::cluster::ClusterSpec;
use burstc::platform::{
    register_work, BurstConfig, Controller, FlareOptions, FlareStatus,
};
use burstc::util::json::Json;

fn wait_running(c: &Controller, id: &str) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while c.flare_status(id) != Some(FlareStatus::Running) {
        assert!(Instant::now() < deadline, "flare never started");
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn main() -> anyhow::Result<()> {
    register_work(
        "tile",
        Arc::new(|p: &Json, _ctx| {
            std::thread::sleep(Duration::from_millis(p.num_or("ms", 10.0) as u64));
            Ok(Json::Null)
        }),
    );

    // Three nodes of different sizes behind one controller. A flare cannot
    // span nodes (the message fabric is node-local), so the biggest
    // admissible burst is the biggest single node: 16 workers.
    let controller = Controller::new_multi(
        vec![
            ("node-0".into(), ClusterSpec::uniform(1, 4)),
            ("node-1".into(), ClusterSpec::uniform(1, 8)),
            ("node-2".into(), ClusterSpec::uniform(2, 8)),
        ],
        CostModel::default(),
        NetParams::scaled(1e-6),
    );
    // Long heartbeat interval: this example controls views explicitly.
    controller.nodes.set_liveness(60_000, 3);
    controller.deploy(
        "tile",
        "tile",
        BurstConfig { strategy: "heterogeneous".into(), ..Default::default() },
    )?;

    println!("registered nodes (the `GET /v1/nodes` view):");
    for s in controller.nodes.node_statuses() {
        println!(
            "  {:<7} alive={} free={:?} total={:?}",
            s.name, s.alive, s.free, s.total
        );
    }

    // --- Explainable placement: an 8-wide flare only fits node-1 whole
    // (node-2 would be half-empty, node-0 cannot host it at all).
    let params = |n: usize| vec![Json::obj(vec![("ms", 10.0.into())]); n];
    let opts = FlareOptions { tenant: Some("acme".into()), ..Default::default() };
    let r = controller.flare("tile", params(8), &opts)?;
    let rec = controller.db.get_flare(&r.flare_id).expect("record kept");
    let placement = rec.placement.expect("every placed flare records a decision");
    println!(
        "\n8-wide flare placed on {:?} (score {:.3}); candidates:",
        rec.node, placement.num_or("score", 0.0)
    );
    for cand in placement.get("candidates").and_then(Json::as_arr).unwrap_or(&[]) {
        match cand.get("reject") {
            Some(reason) => println!("  {:<7} rejected: {reason}", cand.str_or("node", "?")),
            None => println!(
                "  {:<7} score={:.3} (fit {:.2}, locality {:.0}, defrag {:.2})",
                cand.str_or("node", "?"),
                cand.num_or("score", 0.0),
                cand.num_or("fit", 0.0),
                cand.num_or("locality", 0.0),
                cand.num_or("defrag", 0.0),
            ),
        }
    }
    assert_eq!(rec.node.as_deref(), Some("node-1"), "tightest fit wins");

    // --- The stale-view race, on demand: while a 4-wide flare holds all of
    // node-0, feed the registry a heartbeat claiming node-0 is empty. The
    // next flare prefers the lie, node-0's agent refuses against pool
    // ground truth, and spillback re-plans it onto another node.
    let hold = controller.submit_flare(
        "tile",
        vec![Json::obj(vec![("ms", 300.0.into())]); 4],
        &opts,
    )?;
    wait_running(&controller, &hold.flare_id);
    controller.nodes.ingest_view("node-0", vec![4]); // the stale view
    let spilled = controller.submit_flare("tile", params(4), &opts)?;
    let spilled_id = spilled.flare_id.clone();
    spilled.wait()?;
    let rec = controller.db.get_flare(&spilled_id).unwrap();
    println!(
        "\nstale view: node-0 refused, flare spilled to {:?} after {} spillback(s)",
        rec.node,
        rec.placement.as_ref().map_or(0, |p| p.num_or("spillbacks", 0.0) as u64),
    );
    assert_ne!(rec.node.as_deref(), Some("node-0"), "refuser excluded");
    assert!(controller.nodes.refusals_total() >= 1);
    assert!(controller.nodes.spillbacks_total() >= 1);
    hold.wait()?;

    // --- Billing export: everything above ran under tenant "acme"; settled
    // vCPU·seconds are served at `GET /v1/tenants/acme/usage`.
    let billed = controller.tenant_usage("acme").expect("acme has a lane");
    println!("\ntenant acme billed {billed:.4} vCPU·s across 3 flares");
    assert!(billed > 0.0);

    let free: usize = controller
        .nodes
        .node_statuses()
        .iter()
        .map(|s| s.free.iter().sum::<usize>())
        .sum();
    assert_eq!(free, 28, "all reservations released");
    println!(
        "done: all capacity released, {} refusal(s) explained",
        controller.nodes.refusals_total()
    );
    Ok(())
}
