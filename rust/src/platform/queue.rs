//! Flare scheduling pipeline (paper Fig. 4 as a job-level scheduler):
//! **submit → admit → queue → place → execute → complete**.
//!
//! The controller admits flares into a *multi-tenant* queue (`FlareQueue`)
//! instead of packing inline. A dedicated scheduler thread drains the queue
//! with a two-level pick:
//!
//! 1. **Across tenants** — weighted deficit round-robin: each tenant lane
//!    accumulates the vCPUs placed on its behalf, and the lane with the
//!    lowest weighted share goes first, so a heavy tenant flooding the
//!    queue cannot starve a light one (the paper's group-invocation
//!    primitive only pays off if one burst cannot monopolize the cluster).
//! 2. **Within a tenant** — priority classes (`high`/`normal`/`low`), FIFO
//!    within a class.
//!
//! *Backfill* lets a small flare jump a head-of-line flare it cannot
//! unblock, bounded by an anti-starvation pass budget that halts the whole
//! scan once any flare has been passed too often — running flares drain,
//! capacity frees, and the blocked flare goes first.
//!
//! Placement races (a reservation lost between the load snapshot and
//! `InvokerPool::reserve`, cf. SPEAR's two-level scheduling spillback) are
//! retried against a fresh load view up to [`SPILLBACK_RETRIES`] times
//! before the flare simply stays queued.
//!
//! Every queued flare carries a shared [`CancelToken`]; the controller's
//! kill path (`Controller::cancel_flare`) removes queued flares directly
//! and trips the token of running ones, which the execution path observes
//! cooperatively at phase boundaries.
//!
//! **Preemption.** Priorities are not just an ordering hint: when a `high`
//! flare cannot be placed, the scheduler reclaims capacity from running
//! lower-priority flares ([`select_victims`]: lowest priority first,
//! most-recently-started first, minimizing vCPUs reclaimed), trips their
//! tokens with the `Preempted` reason, and — once the workers unwind and
//! release the reservation — re-admits each victim at the head of its lane
//! ([`FlareQueue::requeue_preempted`]) with its original submit time.
//! Within the queue, priority is *strictly dominant across lanes*: every
//! `high` flare is considered before any `normal` one regardless of tenant
//! shares, so reclaimed capacity cannot be re-captured by a lower class in
//! a better-deficit lane (which would livelock the preemption loop).
//!
//! **Deadlines.** A flare may carry an absolute deadline: within a priority
//! class, earliest-deadline-first breaks the FIFO tie, and a flare still
//! queued when its deadline passes is failed fast
//! ([`FlareQueue::take_expired`]) with the terminal `Expired` status
//! instead of occupying the queue it can no longer benefit from.
//!
//! **Accounting.** Placement charges a lane a *provisional* deficit (the
//! vCPU demand); when the reservation is released the charge is settled to
//! the measured vCPU·seconds ([`FlareQueue::settle`]), so a flare that
//! fails, is cancelled, or is preempted early is not billed as if it ran
//! to completion.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Weak};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::controller::{Controller, FlareResult};
use super::db::WorkFn;
use super::invoker::InvokerPool;
use super::node::{NodePlacement, Placer};
use super::packing::{plan, PackSpec, PackingStrategy};
use crate::bcm::BackendKind;
use crate::util::cancel::CancelToken;
use crate::util::json::Json;
use crate::util::sync::{LockRank, RankedMutex};
use crate::util::timing::Stopwatch;

/// How often a blocked flare may be passed by backfilled smaller flares
/// before the queue stops scheduling past it.
pub const MAX_BACKFILL_PASSES: u32 = 16;

/// Re-plan budget when `InvokerPool::reserve` loses a placement race.
pub const SPILLBACK_RETRIES: usize = 3;

/// Tenant lane used when a flare names none.
pub const DEFAULT_TENANT: &str = "default";

/// Scheduling priority class within a tenant lane. Higher classes are
/// placed first; FIFO within a class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

impl Priority {
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "low" => Some(Priority::Low),
            "normal" => Some(Priority::Normal),
            "high" => Some(Priority::High),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }
}

/// A flare admitted to the queue: the fully resolved execution spec.
pub struct QueuedFlare {
    pub flare_id: String,
    pub def_name: String,
    pub work: WorkFn,
    pub params: Vec<Json>,
    /// One worker (= one vCPU) per input param.
    pub burst_size: usize,
    pub strategy: PackingStrategy,
    pub backend: BackendKind,
    pub chunk_size: usize,
    pub faas: bool,
    /// Fair-share lane this flare is accounted to.
    pub tenant: String,
    /// Placement order within the tenant lane.
    pub priority: Priority,
    /// Shared kill switch: tripped by `Controller::cancel_flare` (user) or
    /// the scheduler's preemption path, observed cooperatively by the
    /// execution path.
    pub cancel: CancelToken,
    /// May the scheduler preempt this flare once it runs? (Opt-out via
    /// `FlareOptions::preemptible = false`.)
    pub preemptible: bool,
    /// Absolute deadline: EDF tie-break within a priority class while
    /// queued, and the expiry cutoff for `FlareQueue::take_expired`.
    pub deadline: Option<Instant>,
    /// Times this flare has been preempted and requeued (the livelock
    /// guard: at the policy cap it stops being selectable as a victim).
    pub preempt_count: u32,
    /// Times a run of this flare started with prior worker checkpoints to
    /// restore (mirrors `FlareRecord::resume_count`).
    pub resume_count: u32,
    /// Checkpoint run epoch: bumped at each placement, so checkpoints are
    /// stamped with the run that wrote them. A requeued victim carries its
    /// epoch through the queue, and recovery seeds it from the restored
    /// checkpoints' highest epoch — epochs ascend across preempts *and*
    /// restarts.
    pub ckpt_epoch: u64,
    /// Provisional deficit charged to the lane at placement; settled to
    /// measured vCPU·seconds when the reservation is released.
    pub charged: f64,
    pub(crate) slot: Arc<ResultSlot>,
    /// Started at submit; read at placement to measure queue wait. A
    /// requeued victim keeps its original submit time.
    pub submitted: Stopwatch,
    /// Times a later flare was backfilled past this one while it was blocked.
    pub passed_over: u32,
    /// Set by the last `pop_placeable` scan when this flare was skipped
    /// because its tenant's hard vCPU quota is exhausted (surfaced as the
    /// record's `wait_reason`); cleared on every scan before re-checking.
    pub quota_blocked: bool,
    /// The node this flare last ran on (placement locality hint: warm
    /// containers, checkpoint affinity). Set at each placement; restored
    /// from the flare record across restarts.
    pub prior_node: Option<String>,
    /// Set by the last `pop_placeable` scan when aggregate capacity
    /// sufficed but no single node could host this flare — planning
    /// failed or every candidate refused within the spillback budget
    /// (surfaced as `wait_reason=no_feasible_node`); cleared each scan.
    pub infeasible: bool,
    /// DAG edges: parent flare ids that must reach `Completed` before this
    /// flare leaves the waiting-on-parents holding area and enters the DRR
    /// lanes. Empty for ordinary (non-DAG) flares.
    pub after: Vec<String>,
    /// Nodes the parents ran on, resolved when the last parent completes:
    /// the placer's DAG-locality term scores this flare toward these nodes
    /// so a child stage lands where its parents' outputs already live.
    pub parent_nodes: Vec<String>,
}

/// One-shot result mailbox shared by the execution thread and the waiter.
pub(crate) struct ResultSlot {
    result: RankedMutex<Option<Result<FlareResult>>>,
    cv: Condvar,
}

impl ResultSlot {
    pub(crate) fn new() -> ResultSlot {
        ResultSlot {
            result: RankedMutex::new(LockRank::ResultSlot, None),
            cv: Condvar::new(),
        }
    }

    pub(crate) fn deliver(&self, r: Result<FlareResult>) {
        *self.result.lock() = Some(r);
        self.cv.notify_all();
    }

    fn wait_take(&self) -> Result<FlareResult> {
        let mut guard = self.result.lock();
        loop {
            if let Some(r) = guard.take() {
                return r;
            }
            guard = guard.wait(&self.cv);
        }
    }

    /// Bounded `wait_take`: `None` on timeout, leaving the result (if it
    /// arrives later) for a subsequent wait.
    fn wait_take_timeout(&self, timeout: Duration) -> Option<Result<FlareResult>> {
        let deadline = Instant::now() + timeout;
        let mut guard = self.result.lock();
        loop {
            if let Some(r) = guard.take() {
                return Some(r);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, _) = guard.wait_timeout(&self.cv, deadline - now);
            guard = g;
        }
    }

    fn is_done(&self) -> bool {
        self.result.lock().is_some()
    }
}

/// Handle to an in-flight flare returned by `Controller::submit_flare`.
/// Live status is in `BurstDb` (`Controller::flare_status`); the handle
/// carries the final `FlareResult` to the submitter.
pub struct FlareHandle {
    pub flare_id: String,
    pub(crate) slot: Arc<ResultSlot>,
}

impl FlareHandle {
    /// Block until the flare completes (or fails) and take its result.
    pub fn wait(self) -> Result<FlareResult> {
        self.slot.wait_take()
    }

    /// Bounded wait: block until the flare completes or `timeout` elapses,
    /// returning `None` on timeout with the result left for a later
    /// `wait`/`wait_timeout`. This is the interruptible building block the
    /// HTTP server loops against its stop flag, so shutdown never parks on
    /// a flare's full duration.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Result<FlareResult>> {
        self.slot.wait_take_timeout(timeout)
    }

    /// Non-blocking: has the flare reached a terminal state?
    pub fn is_finished(&self) -> bool {
        self.slot.is_done()
    }
}

/// Plan + reserve with bounded spillback: each attempt plans against a fresh
/// snapshot of the pool's free capacity, so losing a reservation race to a
/// concurrent placement triggers a re-plan instead of a failure. Returns
/// `None` when the flare does not fit the current load (stay queued) or the
/// retry budget is exhausted.
///
/// Today the single scheduler thread is the only `reserve` caller (others
/// only `release`, which cannot defeat a planned reservation), so the retry
/// branch is dormant by construction; it becomes live the moment placement
/// gains a second actor — SPEAR-style per-node schedulers, a second
/// controller, or direct `reserve` users — which is the two-level design
/// this module is built toward.
pub fn place_with_spillback(
    pool: &InvokerPool,
    strategy: PackingStrategy,
    burst_size: usize,
    retries: usize,
) -> Option<Vec<PackSpec>> {
    place_with_spillback_observed(pool, strategy, burst_size, retries, |_| {})
}

/// Test seam: `between_plan_and_reserve(i)` runs after attempt `i` planned
/// against its load snapshot but before it reserves — exactly the window a
/// concurrent placement can race into.
fn place_with_spillback_observed(
    pool: &InvokerPool,
    strategy: PackingStrategy,
    burst_size: usize,
    retries: usize,
    mut between_plan_and_reserve: impl FnMut(usize),
) -> Option<Vec<PackSpec>> {
    for attempt in 0..=retries {
        let free = pool.free_vcpus();
        let packs = plan(strategy, burst_size, &free).ok()?;
        between_plan_and_reserve(attempt);
        if pool.reserve(&packs).is_ok() {
            return Some(packs);
        }
        // Reservation lost to a concurrent placement; loop re-plans
        // against the fresh load view.
    }
    None
}

/// A running flare the preemption policy may select as a victim.
#[derive(Debug, Clone)]
pub struct PreemptCandidate {
    pub flare_id: String,
    pub priority: Priority,
    /// vCPUs its reservation holds (= burst size).
    pub vcpus: usize,
    /// Placement sequence number; higher = started more recently.
    pub seq: u64,
    /// Node hosting the reservation: victims are only useful if they free
    /// *contiguous* capacity on one node a flare can actually land on.
    pub node: String,
}

/// Pick which running flares on ONE node to preempt: lowest priority
/// first, most-recently-started first within a priority class (old flares
/// keep their progress), then a trim pass drops every victim whose reclaim
/// turned out redundant — largest first — so the set of reclaimed vCPUs is
/// minimal. `None` when the candidates cannot cover `needed`: a partial
/// preemption would destroy work without unblocking anything.
fn victims_on_node(
    cands: &[&PreemptCandidate],
    needed: usize,
) -> Option<(usize, Vec<String>)> {
    let mut order: Vec<&PreemptCandidate> = cands.to_vec();
    order.sort_by(|a, b| a.priority.cmp(&b.priority).then(b.seq.cmp(&a.seq)));
    let mut picked: Vec<&PreemptCandidate> = Vec::new();
    let mut sum = 0usize;
    for c in order {
        if sum >= needed {
            break;
        }
        sum += c.vcpus;
        picked.push(c);
    }
    if sum < needed {
        return None;
    }
    let mut by_size: Vec<usize> = (0..picked.len()).collect();
    by_size.sort_by(|&a, &b| picked[b].vcpus.cmp(&picked[a].vcpus));
    let mut keep = vec![true; picked.len()];
    for i in by_size {
        if sum - picked[i].vcpus >= needed {
            sum -= picked[i].vcpus;
            keep[i] = false;
        }
    }
    let ids = picked
        .iter()
        .zip(keep)
        .filter(|(_, k)| *k)
        .map(|(c, _)| c.flare_id.clone())
        .collect();
    Some((sum, ids))
}

/// Fragmentation-aware victim selection: `needed_by_node` maps each node
/// that *could* host the starved flare to the vCPUs still missing there
/// (node total ≥ burst, so freeing that much makes the flare placeable on
/// that node). Candidate victims are grouped by hosting node and each
/// node's minimal cover is computed independently; the cheapest feasible
/// single-node plan wins (fewest vCPUs reclaimed, then fewest victims,
/// then node name for determinism). Empty when no node's candidates can
/// cover its shortfall — preempting across nodes would destroy work
/// without freeing contiguous capacity anywhere.
pub fn select_victims(
    cands: &[PreemptCandidate],
    needed_by_node: &BTreeMap<String, usize>,
) -> Vec<String> {
    let mut best: Option<(usize, usize, Vec<String>)> = None;
    for (node, &needed) in needed_by_node {
        if needed == 0 {
            continue;
        }
        let on_node: Vec<&PreemptCandidate> =
            cands.iter().filter(|c| &c.node == node).collect();
        if let Some((reclaimed, ids)) = victims_on_node(&on_node, needed) {
            let cheaper = match &best {
                None => true,
                Some((r, n, _)) => (reclaimed, ids.len()) < (*r, *n),
            };
            if cheaper {
                best = Some((reclaimed, ids.len(), ids));
            }
        }
    }
    best.map(|(_, _, ids)| ids).unwrap_or_default()
}

/// EDF comparison: does deadline `a` come strictly before `b`? A missing
/// deadline sorts after every real one (and FIFO among themselves).
fn deadline_before(a: Option<Instant>, b: Option<Instant>) -> bool {
    match (a, b) {
        (Some(a), Some(b)) => a < b,
        (Some(_), None) => true,
        _ => false,
    }
}

/// One tenant's lane: its pending flares (priority-then-FIFO order is the
/// insertion order) plus its deficit accounting.
struct TenantLane {
    name: String,
    jobs: VecDeque<QueuedFlare>,
    /// vCPUs placed on behalf of this tenant so far (the queued vCPU·time
    /// proxy the deficit round-robin ranks lanes by).
    consumed: f64,
    /// Fair-share weight; a lane with weight 2 is entitled to twice the
    /// placed vCPUs of a weight-1 lane.
    weight: f64,
    /// vCPUs this tenant holds *right now* (incremented at placement,
    /// decremented at `settle` when the reservation is released) — the
    /// quantity the hard quota caps.
    placed: usize,
    /// Hard cap on concurrently placed vCPUs (`None` = unlimited). A
    /// flare over the cap stays queued with a `quota_blocked` reason even
    /// when the cluster has free capacity; admission is unaffected.
    quota: Option<usize>,
    /// Lifetime vCPU·seconds settled for this tenant (the billing meter:
    /// every `settle` adds its *measured* charge). Restored from the WAL's
    /// absolute-total usage entries at recovery.
    billed_vcpu_s: f64,
}

impl TenantLane {
    fn new(name: &str) -> TenantLane {
        TenantLane {
            name: name.to_string(),
            jobs: VecDeque::new(),
            consumed: 0.0,
            weight: 1.0,
            placed: 0,
            quota: None,
            billed_vcpu_s: 0.0,
        }
    }

    /// Weighted share: lanes with the lowest share are scheduled first.
    fn share(&self) -> f64 {
        self.consumed / self.weight
    }
}

/// One tenant's scheduling policy and live usage (the `GET /v1/tenants`
/// view; weight and quota are also what the durable store persists).
#[derive(Debug, Clone, PartialEq)]
pub struct TenantPolicy {
    pub tenant: String,
    /// Fair-share weight (DRR entitlement).
    pub weight: f64,
    /// Hard cap on concurrently placed vCPUs (`None` = unlimited).
    pub quota: Option<usize>,
    /// vCPUs currently placed for this tenant.
    pub placed_vcpus: usize,
    /// Flares waiting in this tenant's lane.
    pub queued: usize,
    /// Lifetime settled vCPU·seconds (the billing meter).
    pub billed_vcpu_s: f64,
}

impl TenantPolicy {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("tenant", self.tenant.as_str().into()),
            ("weight", self.weight.into()),
            ("placed_vcpus", self.placed_vcpus.into()),
            ("queued", self.queued.into()),
            ("vcpu_seconds", self.billed_vcpu_s.into()),
        ];
        if let Some(q) = self.quota {
            fields.push(("quota", q.into()));
        }
        Json::obj(fields)
    }
}

/// Multi-tenant capacity-aware queue: weighted deficit round-robin across
/// tenant lanes, priority-then-FIFO within a lane, bounded backfill with a
/// global anti-starvation guard.
pub struct FlareQueue {
    tenants: Vec<TenantLane>,
    max_backfill_passes: u32,
    /// Waiting-on-parents holding area, **outside** the DRR lanes: a DAG
    /// child parks here until every parent reaches `Completed`, so a
    /// blocked child neither consumes backfill passes nor skews lane
    /// deficits while it cannot possibly be placed. FIFO by admission;
    /// promotion into the lanes goes through the ordinary `push` (so
    /// priority/EDF ordering applies from the moment it is runnable).
    waiting: VecDeque<QueuedFlare>,
}

impl FlareQueue {
    pub fn new(max_backfill_passes: u32) -> FlareQueue {
        FlareQueue {
            tenants: Vec::new(),
            max_backfill_passes,
            waiting: VecDeque::new(),
        }
    }

    /// Set a tenant's fair-share weight (creating its lane if needed).
    pub fn set_tenant_weight(&mut self, tenant: &str, weight: f64) {
        let li = self.lane_index(tenant);
        self.tenants[li].weight = weight.max(f64::MIN_POSITIVE);
    }

    /// Set (or clear, with `None`) a tenant's hard cap on concurrently
    /// placed vCPUs. Purely a placement-time gate: admission still
    /// succeeds and DRR deficits are untouched by quota-blocked waits.
    pub fn set_tenant_quota(&mut self, tenant: &str, quota: Option<usize>) {
        let li = self.lane_index(tenant);
        self.tenants[li].quota = quota;
    }

    /// A tenant's current `(weight, quota)` policy, if its lane exists.
    pub fn policy(&self, tenant: &str) -> Option<(f64, Option<usize>)> {
        self.tenants
            .iter()
            .find(|t| t.name == tenant)
            .map(|t| (t.weight, t.quota))
    }

    /// Every tenant lane's policy and live usage, sorted by name (the
    /// `GET /v1/tenants` view).
    pub fn tenant_policies(&self) -> Vec<TenantPolicy> {
        let mut v: Vec<TenantPolicy> = self
            .tenants
            .iter()
            .map(|t| TenantPolicy {
                tenant: t.name.clone(),
                weight: t.weight,
                quota: t.quota,
                placed_vcpus: t.placed,
                queued: t.jobs.len(),
                billed_vcpu_s: t.billed_vcpu_s,
            })
            .collect();
        v.sort_by(|a, b| a.tenant.cmp(&b.tenant));
        v
    }

    /// Ids of queued flares the last scan skipped for quota exhaustion.
    pub fn quota_blocked_ids(&self) -> Vec<String> {
        self.tenants
            .iter()
            .flat_map(|t| t.jobs.iter())
            .filter(|j| j.quota_blocked)
            .map(|j| j.flare_id.clone())
            .collect()
    }

    /// Ids of queued flares the last scan found infeasible: aggregate
    /// capacity sufficed, but no single node could host them.
    pub fn infeasible_ids(&self) -> Vec<String> {
        self.tenants
            .iter()
            .flat_map(|t| t.jobs.iter())
            .filter(|j| j.infeasible)
            .map(|j| j.flare_id.clone())
            .collect()
    }

    /// Lowest weighted share among lanes that currently hold jobs.
    fn min_active_share(&self) -> f64 {
        self.tenants
            .iter()
            .filter(|t| !t.jobs.is_empty())
            .map(TenantLane::share)
            .fold(f64::INFINITY, f64::min)
    }

    fn lane_index(&mut self, tenant: &str) -> usize {
        match self.tenants.iter().position(|t| t.name == tenant) {
            Some(i) => i,
            None => {
                self.tenants.push(TenantLane::new(tenant));
                self.tenants.len() - 1
            }
        }
    }

    /// Shared lane bookkeeping for `push`/`requeue_preempted`: the
    /// activation snap and fresh-epoch reset, returning the lane index.
    fn prep_lane(&mut self, tenant: &str) -> usize {
        // A lane (re)entering service snaps its consumption forward to the
        // current fair frontier: idle time is not banked, so neither a
        // brand-new tenant nor one returning from a quiet spell gets an
        // unbounded run of placements before everyone else is served again.
        let frontier = self.min_active_share();
        if frontier.is_infinite() {
            // The queue fully drained: start a fresh fairness epoch. Without
            // this, a veteran lane's historical consumption would let any
            // newcomer starve it for an unbounded catch-up run (the inverse
            // of the banked-idle-time problem the snap below solves).
            for t in &mut self.tenants {
                t.consumed = 0.0;
            }
        }
        let li = self.lane_index(tenant);
        let lane = &mut self.tenants[li];
        if lane.jobs.is_empty() && frontier.is_finite() {
            lane.consumed = lane.consumed.max(frontier * lane.weight);
        }
        li
    }

    pub fn push(&mut self, job: QueuedFlare) {
        let li = self.prep_lane(&job.tenant);
        let lane = &mut self.tenants[li];
        // Priority, then EDF within a class, then FIFO: insert before the
        // first strictly lower priority or the first same-class job with a
        // strictly later deadline (deadline-less jobs sort last in class).
        let at = lane
            .jobs
            .iter()
            .position(|q| {
                q.priority < job.priority
                    || (q.priority == job.priority
                        && deadline_before(job.deadline, q.deadline))
            })
            .unwrap_or(lane.jobs.len());
        lane.jobs.insert(at, job);
    }

    /// Re-admit a preempted flare at the head of its priority class within
    /// its lane: it keeps its original submit time, so being preempted
    /// must not also cost it queue position behind later arrivals.
    pub fn requeue_preempted(&mut self, mut job: QueuedFlare) {
        job.passed_over = 0;
        let li = self.prep_lane(&job.tenant);
        let lane = &mut self.tenants[li];
        let at = lane
            .jobs
            .iter()
            .position(|q| q.priority <= job.priority)
            .unwrap_or(lane.jobs.len());
        lane.jobs.insert(at, job);
    }

    /// Remove and return every queued flare whose deadline has passed: the
    /// scheduler fails these fast with `FlareStatus::Expired` instead of
    /// letting them occupy the queue they can no longer benefit from.
    /// Children in the waiting-on-parents area are covered too — a
    /// deadline lapses the same whether a flare waits on capacity or on a
    /// parent.
    pub fn take_expired(&mut self, now: Instant) -> Vec<QueuedFlare> {
        let mut out = Vec::new();
        for lane in &mut self.tenants {
            let mut i = 0;
            while i < lane.jobs.len() {
                if lane.jobs[i].deadline.is_some_and(|d| now >= d) {
                    out.push(lane.jobs.remove(i).expect("index in range"));
                } else {
                    i += 1;
                }
            }
        }
        let mut i = 0;
        while i < self.waiting.len() {
            if self.waiting[i].deadline.is_some_and(|d| now >= d) {
                out.push(self.waiting.remove(i).expect("index in range"));
            } else {
                i += 1;
            }
        }
        out
    }

    /// Park a DAG child in the waiting-on-parents holding area (outside
    /// the DRR lanes — no backfill passes, no deficit skew while blocked).
    pub fn park_waiting(&mut self, job: QueuedFlare) {
        self.waiting.push_back(job);
    }

    /// Snapshot of the holding area: `(flare_id, after)` per waiting
    /// child. The controller resolves parent statuses against the db with
    /// no queue lock held, then promotes/fails by id.
    pub fn waiting_edges(&self) -> Vec<(String, Vec<String>)> {
        self.waiting
            .iter()
            .map(|j| (j.flare_id.clone(), j.after.clone()))
            .collect()
    }

    /// Remove one child from the holding area by id (promotion to the
    /// lanes, fail-fast, or cancellation). `None` when a concurrent
    /// cancel already took it.
    pub fn take_waiting(&mut self, flare_id: &str) -> Option<QueuedFlare> {
        let i = self.waiting.iter().position(|j| j.flare_id == flare_id)?;
        self.waiting.remove(i)
    }

    /// Number of children parked on unfinished parents.
    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    /// Burst size of the queued flare of `class` that has waited longest
    /// (`None` if the class is empty): the flare the preemption policy
    /// reclaims capacity for. Quota-blocked flares are excluded — they
    /// wait on their *own tenant's* cap, so preempting other tenants'
    /// work could never unblock them.
    pub fn oldest_of_class(&self, class: Priority) -> Option<usize> {
        self.tenants
            .iter()
            .flat_map(|t| t.jobs.iter())
            .filter(|j| j.priority == class && !j.quota_blocked)
            .max_by(|a, b| a.submitted.elapsed().cmp(&b.submitted.elapsed()))
            .map(|j| j.burst_size)
    }

    /// Replace a lane's provisional placement charge with the measured
    /// vCPU·seconds the flare actually held its reservation (bugfix: a
    /// flare that fails, is cancelled, or is preempted early must not be
    /// billed as if it ran to completion). Clamped at zero: a fresh
    /// fairness epoch can zero a lane while one of its flares is still
    /// running, and that flare's settle must not push the lane into
    /// negative consumption (an unearned advantage in the new epoch).
    /// Returns the tenant's new lifetime billed vCPU·seconds total — the
    /// absolute value the controller journals as a `usage` WAL entry
    /// (absolute so replay is an idempotent overwrite, never a re-sum).
    pub fn settle(&mut self, tenant: &str, provisional: f64, measured: f64) -> f64 {
        let li = self.lane_index(tenant);
        let lane = &mut self.tenants[li];
        lane.consumed = (lane.consumed + measured - provisional).max(0.0);
        // The reservation is released: those vCPUs no longer count against
        // the tenant's hard quota. (`provisional` is the burst size the
        // placement charged, so this mirrors `pop_placeable` exactly.)
        lane.placed = lane.placed.saturating_sub(provisional as usize);
        lane.billed_vcpu_s += measured;
        lane.billed_vcpu_s
    }

    /// Recovery: restore a tenant's lifetime billed total from the WAL's
    /// last absolute `usage` entry (creating its lane if needed).
    pub fn seed_billed(&mut self, tenant: &str, total: f64) {
        let li = self.lane_index(tenant);
        self.tenants[li].billed_vcpu_s = total;
    }

    /// One tenant's lifetime billed vCPU·seconds, if its lane exists.
    pub fn usage_of(&self, tenant: &str) -> Option<f64> {
        self.tenants
            .iter()
            .find(|t| t.name == tenant)
            .map(|t| t.billed_vcpu_s)
    }

    pub fn len(&self) -> usize {
        self.tenants.iter().map(|t| t.jobs.len()).sum::<usize>() + self.waiting.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.iter().all(|t| t.jobs.is_empty()) && self.waiting.is_empty()
    }

    /// Queue depth per tenant, lanes with pending flares only, sorted by
    /// tenant name (the `/metrics` view).
    pub fn depth_by_tenant(&self) -> Vec<(String, usize)> {
        let mut v: Vec<(String, usize)> = self
            .tenants
            .iter()
            .filter(|t| !t.jobs.is_empty())
            .map(|t| (t.name.clone(), t.jobs.len()))
            .collect();
        v.sort();
        v
    }

    /// Remove a queued flare by id (the cancel-while-queued kill path).
    /// Children parked on unfinished parents are cancellable too.
    pub fn remove(&mut self, flare_id: &str) -> Option<QueuedFlare> {
        for lane in &mut self.tenants {
            if let Some(i) = lane.jobs.iter().position(|j| j.flare_id == flare_id) {
                return lane.jobs.remove(i);
            }
        }
        self.take_waiting(flare_id)
    }

    pub(crate) fn drain(&mut self) -> Vec<QueuedFlare> {
        let mut out: Vec<QueuedFlare> =
            self.tenants.iter_mut().flat_map(|t| t.jobs.drain(..)).collect();
        out.extend(self.waiting.drain(..));
        out
    }

    /// Remove and return the first flare that can be placed right now,
    /// together with its committed node placement (node, reserved pack
    /// plan, score, decision record).
    ///
    /// Three-level pick: priority classes are scanned high-to-low across
    /// the *whole* queue — priority is strictly dominant over tenant
    /// shares, so capacity reclaimed by preemption cannot be re-captured
    /// by a lower class in a better-deficit lane. Within a class, tenant
    /// lanes go in ascending weighted-share order (deficit round-robin —
    /// ties broken by name for determinism), and within a lane jobs keep
    /// their EDF-then-FIFO insertion order. A flare that does not fit is
    /// skipped (backfill) unless it has already been passed
    /// `max_backfill_passes` times, in which case the whole scan stops and
    /// nothing may start — running flares drain, capacity frees, and the
    /// blocked flare goes first. A successful placement charges the lane's
    /// deficit with the flare's vCPU demand (provisional; settled to
    /// measured vCPU·seconds on release).
    ///
    /// **Quotas.** A lane with a hard vCPU quota skips any flare that
    /// would push its concurrently placed vCPUs past the cap, *before*
    /// planning. A quota skip is deliberately invisible to the fairness
    /// machinery: it does not count as a backfill pass (a quota-blocked
    /// flare waits on its own tenant's running work, so halting the whole
    /// scan for it would stall every other tenant for nothing) and it does
    /// not touch DRR deficits. The skipped flare is marked
    /// `quota_blocked` for status visibility.
    ///
    /// **Infeasibility.** A flare that passes the aggregate free-capacity
    /// pre-check yet cannot be placed by the `placer` (no single node can
    /// host it, or every candidate refused within the spillback budget) is
    /// marked `infeasible` for status visibility
    /// (`wait_reason=no_feasible_node`); the skip still counts as a
    /// backfill pass, exactly like any other failed placement.
    pub fn pop_placeable(
        &mut self,
        placer: &dyn Placer,
    ) -> Option<(QueuedFlare, NodePlacement)> {
        // Re-derive quota-blocked and infeasible marks from scratch each
        // scan.
        for lane in &mut self.tenants {
            for job in &mut lane.jobs {
                job.quota_blocked = false;
                job.infeasible = false;
            }
        }
        let mut lane_order: Vec<usize> = (0..self.tenants.len())
            .filter(|&l| !self.tenants[l].jobs.is_empty())
            .collect();
        lane_order.sort_by(|&a, &b| {
            self.tenants[a]
                .share()
                .total_cmp(&self.tenants[b].share())
                .then_with(|| self.tenants[a].name.cmp(&self.tenants[b].name))
        });

        // Cheap necessary condition checked before running the packing
        // planner per job: a burst larger than the total free capacity can
        // never be placed, and on a saturated cluster that is every job —
        // this keeps the periodic rescan O(queue) comparisons, not
        // O(queue) plan() calls, under the queue lock. (Skipping a job this
        // way is exactly a failed placement: pass accounting is identical.)
        let total_free: usize = placer.total_free();

        let mut chosen: Option<(usize, usize, NodePlacement)> = None;
        let mut skipped: Vec<(usize, usize)> = Vec::new();
        let mut quota_hits: Vec<(usize, usize)> = Vec::new();
        let mut infeasible_hits: Vec<(usize, usize)> = Vec::new();
        'scan: for class in [Priority::High, Priority::Normal, Priority::Low] {
            for &l in &lane_order {
                let (lane_placed, lane_quota) =
                    (self.tenants[l].placed, self.tenants[l].quota);
                for (j, job) in self.tenants[l].jobs.iter().enumerate() {
                    if job.priority != class {
                        continue;
                    }
                    // Hard quota: checked before planning, never counted
                    // as a backfill pass (see method docs).
                    if lane_quota.is_some_and(|q| lane_placed + job.burst_size > q) {
                        quota_hits.push((l, j));
                        continue;
                    }
                    let placed = if job.burst_size <= total_free {
                        let p = placer.place(job);
                        if p.is_none() {
                            // Fit the aggregate view but no node took it.
                            infeasible_hits.push((l, j));
                        }
                        p
                    } else {
                        None
                    };
                    if let Some(placement) = placed {
                        chosen = Some((l, j, placement));
                        break 'scan;
                    }
                    if job.passed_over >= self.max_backfill_passes {
                        break 'scan; // starvation guard: stop the whole scan
                    }
                    skipped.push((l, j));
                }
            }
        }
        // Mark quota-blocked and infeasible flares whether or not anything
        // placed — the common case is "nothing else is queued, yet this
        // waits".
        for &(ql, qj) in &quota_hits {
            self.tenants[ql].jobs[qj].quota_blocked = true;
        }
        for &(il, ij) in &infeasible_hits {
            self.tenants[il].jobs[ij].infeasible = true;
        }
        let (l, j, placement) = chosen?;
        for &(sl, sj) in &skipped {
            self.tenants[sl].jobs[sj].passed_over += 1;
        }
        let mut job = self.tenants[l].jobs.remove(j).expect("index in range");
        job.charged = job.burst_size as f64;
        self.tenants[l].consumed += job.charged;
        self.tenants[l].placed += job.burst_size;
        Some((job, placement))
    }
}

/// State shared between the controller, the scheduler thread, and the
/// per-flare execution threads.
pub(crate) struct SchedState {
    pub(crate) queue: RankedMutex<FlareQueue>,
    /// Batched-admission inbox: `submit_flare` appends here (a short,
    /// uncontended push) instead of taking the big queue lock — the
    /// scheduler adopts the whole batch at the start of its next pass
    /// under a single queue lock, in submission order, so DRR fairness,
    /// priority, quota, and preemption semantics are untouched. Recovery
    /// and preempt-requeue bypass the inbox (the scheduler is paused /
    /// the job re-enters at the head of its lane).
    pub(crate) inbox: RankedMutex<Vec<QueuedFlare>>,
    cv: Condvar,
    /// Set by `wake` so a notification between scheduling passes is never
    /// lost (the scheduler re-checks before sleeping).
    dirty: AtomicBool,
    shutdown: AtomicBool,
    /// While set, scheduling passes are skipped entirely: recovery
    /// replays tenant policy and re-admits flares with the scheduler held
    /// off, so nothing can be placed under not-yet-restored weights or
    /// quotas. Released by `resume`.
    paused: AtomicBool,
    /// Scheduler hot-path counters (the control-plane bench reads these
    /// through `/metrics`): completed passes, flares admitted from the
    /// inbox, and accumulated active pass time.
    pub(crate) passes: AtomicU64,
    pub(crate) admitted: AtomicU64,
    pub(crate) pass_micros: AtomicU64,
}

impl SchedState {
    pub(crate) fn new(max_backfill_passes: u32) -> Arc<SchedState> {
        Arc::new(SchedState {
            queue: RankedMutex::new(
                LockRank::SchedQueue,
                FlareQueue::new(max_backfill_passes),
            ),
            inbox: RankedMutex::new(LockRank::Inbox, Vec::new()),
            cv: Condvar::new(),
            dirty: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
            paused: AtomicBool::new(false),
            passes: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            pass_micros: AtomicU64::new(0),
        })
    }

    /// Hold off scheduling passes (recovery replay window).
    pub(crate) fn pause(&self) {
        self.paused.store(true, Ordering::Release);
    }

    /// Release a `pause` and kick a scheduling pass.
    pub(crate) fn resume(&self) {
        self.paused.store(false, Ordering::Release);
        self.wake();
    }

    /// Nudge the scheduler: a flare was submitted or capacity was freed.
    pub(crate) fn wake(&self) {
        self.dirty.store(true, Ordering::Release);
        self.cv.notify_all();
    }

    pub(crate) fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        self.cv.notify_all();
    }
}

/// The scheduler thread body: drain placeable flares, sleep until woken.
/// Holds only a `Weak` controller so dropping the last external `Arc`
/// (which triggers `Controller::drop` → `SchedState::shutdown`) ends it.
pub(crate) fn scheduler_loop(state: Arc<SchedState>, controller: Weak<Controller>) {
    // Fail whatever never got placed so waiters don't hang forever — on
    // clean shutdown *and* if the scheduler thread itself panics.
    struct DrainOnExit(Arc<SchedState>);
    impl Drop for DrainOnExit {
        fn drop(&mut self) {
            // On the panic path the queue mutex may be poisoned (the panic
            // can originate under the lock); recover the inner state — a
            // second panic here would abort the process.
            let mut leftovers = std::mem::take(&mut *self.0.inbox.lock_recover());
            leftovers.extend(self.0.queue.lock_recover().drain());
            for job in leftovers {
                job.slot.deliver(Err(anyhow!(
                    "scheduler stopped before flare '{}' was placed",
                    job.flare_id
                )));
            }
        }
    }
    let _drain = DrainOnExit(state.clone());

    while !state.shutdown.load(Ordering::Acquire) {
        if state.paused.load(Ordering::Acquire) {
            // Recovery replay in progress: nothing may be placed until
            // tenant weights and quotas are reinstated.
        } else if let Some(c) = controller.upgrade() {
            let pass_started = Instant::now();
            // Batched admission: adopt every flare submitted since the
            // last pass in one queue lock (in submission order), instead
            // of paying a queue-lock acquisition per submit.
            let batch = std::mem::take(&mut *state.inbox.lock());
            if !batch.is_empty() {
                state.admitted.fetch_add(batch.len() as u64, Ordering::Relaxed);
                let mut q = state.queue.lock();
                for job in batch {
                    if job.after.is_empty() {
                        q.push(job);
                    } else {
                        // DAG child: park outside the lanes until its
                        // parents resolve (the pass below promotes
                        // already-satisfied children immediately).
                        q.park_waiting(job);
                    }
                }
            }
            // DAG pass: promote children whose parents all completed into
            // the lanes, fail fast the ones whose parents failed.
            c.resolve_dag_waiters();
            // Deadline pass first: a flare whose deadline lapsed while
            // queued must fail fast, never be placed.
            c.expire_overdue_queued();
            // Node liveness pass: drive heartbeats, declare silent nodes
            // dead, and fail over their flares.
            c.node_maintenance();
            loop {
                let placed = state.queue.lock().pop_placeable(c.nodes.as_ref());
                match placed {
                    Some((job, placement)) => {
                        Controller::spawn_execution(&c, job, placement, &state)
                    }
                    None => break,
                }
            }
            // Surface quota-blocked and no-feasible-node waits in the
            // flare records.
            c.sync_wait_reasons();
            // Nothing placeable left: reclaim capacity for a starved
            // high-priority flare by preempting lower-priority runners.
            c.preempt_for_starved_high_flare();
            state.passes.fetch_add(1, Ordering::Relaxed);
            state
                .pass_micros
                .fetch_add(pass_started.elapsed().as_micros() as u64, Ordering::Relaxed);
        }
        let guard = state.queue.lock();
        if state.shutdown.load(Ordering::Acquire) {
            break;
        }
        if !state.dirty.swap(false, Ordering::AcqRel) {
            // Timeout bounds the window of any missed wake-up.
            let _ = guard.wait_timeout(&state.cv, Duration::from_millis(25));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;

    fn job(id: &str, size: usize) -> QueuedFlare {
        job_for(id, size, DEFAULT_TENANT, Priority::Normal)
    }

    fn job_for(id: &str, size: usize, tenant: &str, priority: Priority) -> QueuedFlare {
        QueuedFlare {
            flare_id: id.to_string(),
            def_name: "d".into(),
            work: Arc::new(|_p, _ctx| Ok(Json::Null)),
            params: vec![Json::Null; size],
            burst_size: size,
            strategy: PackingStrategy::Heterogeneous,
            backend: BackendKind::DragonflyList,
            chunk_size: 1024,
            faas: false,
            tenant: tenant.to_string(),
            priority,
            cancel: CancelToken::new(),
            preemptible: true,
            deadline: None,
            preempt_count: 0,
            resume_count: 0,
            ckpt_epoch: 0,
            charged: 0.0,
            slot: Arc::new(ResultSlot::new()),
            submitted: Stopwatch::start(),
            passed_over: 0,
            quota_blocked: false,
            prior_node: None,
            infeasible: false,
            after: Vec::new(),
            parent_nodes: Vec::new(),
        }
    }

    fn job_with_deadline(id: &str, size: usize, deadline_ms: Option<u64>) -> QueuedFlare {
        let mut j = job_for(id, size, "t", Priority::Normal);
        j.deadline = deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
        j
    }

    /// Pop, assert the id, and release the reservation (serial-capacity
    /// helper for the fairness tests).
    fn pop_release(q: &mut FlareQueue, pool: &InvokerPool) -> String {
        let (job, p) = q.pop_placeable(pool).expect("placeable");
        pool.release(&p.packs);
        job.flare_id
    }

    #[test]
    fn fifo_order_when_everything_fits() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 16));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.push(job("a", 4));
        q.push(job("b", 4));
        let (first, p) = q.pop_placeable(&pool).unwrap();
        assert_eq!(first.flare_id, "a");
        assert_eq!(p.packs.iter().map(PackSpec::vcpus).sum::<usize>(), 4);
        assert_eq!(p.node, crate::platform::node::DEFAULT_NODE);
        let (second, _) = q.pop_placeable(&pool).unwrap();
        assert_eq!(second.flare_id, "b");
        assert!(q.pop_placeable(&pool).is_none());
        assert_eq!(pool.free_vcpus(), vec![8]);
    }

    #[test]
    fn backfill_lets_small_flare_pass_blocked_large_one() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 8));
        // 6 of 8 vCPUs already in use.
        pool.reserve(&[PackSpec { invoker_id: 0, workers: (0..6).collect() }]).unwrap();
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.push(job("big", 8)); // blocked: needs the whole machine
        q.push(job("small", 2));
        let (picked, _) = q.pop_placeable(&pool).unwrap();
        assert_eq!(picked.flare_id, "small");
        // The blocked head stays, with its pass recorded.
        assert_eq!(q.len(), 1);
        assert!(q.pop_placeable(&pool).is_none());
    }

    #[test]
    fn starvation_guard_stops_backfill_past_exhausted_flare() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 8));
        pool.reserve(&[PackSpec { invoker_id: 0, workers: (0..6).collect() }]).unwrap();
        let mut q = FlareQueue::new(2);
        q.push(job("big", 8));
        q.push(job("s1", 2));
        q.push(job("s2", 2));
        q.push(job("s3", 2));
        // Two backfills allowed...
        assert_eq!(q.pop_placeable(&pool).unwrap().0.flare_id, "s1");
        pool.release(&[PackSpec { invoker_id: 0, workers: vec![0, 1] }]);
        assert_eq!(q.pop_placeable(&pool).unwrap().0.flare_id, "s2");
        pool.release(&[PackSpec { invoker_id: 0, workers: vec![0, 1] }]);
        // ...then the guard trips: s3 would fit, but "big" has priority now.
        assert!(q.pop_placeable(&pool).is_none());
        // Once the rest of the machine frees, the big flare goes first.
        pool.release(&[PackSpec { invoker_id: 0, workers: (0..6).collect() }]);
        let (big, big_p) = q.pop_placeable(&pool).unwrap();
        assert_eq!(big.flare_id, "big");
        pool.release(&big_p.packs);
        assert_eq!(q.pop_placeable(&pool).unwrap().0.flare_id, "s3");
    }

    #[test]
    fn tenants_alternate_under_equal_demand() {
        // Serial capacity (every flare needs the whole machine): a flooding
        // tenant and a light tenant must interleave, not FIFO.
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 4));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.push(job_for("h1", 4, "heavy", Priority::Normal));
        q.push(job_for("h2", 4, "heavy", Priority::Normal));
        q.push(job_for("h3", 4, "heavy", Priority::Normal));
        q.push(job_for("l1", 4, "light", Priority::Normal));
        q.push(job_for("l2", 4, "light", Priority::Normal));
        // Shares start equal; ties break by name ("heavy" < "light"), then
        // the deficit alternates the lanes.
        assert_eq!(pop_release(&mut q, &pool), "h1");
        assert_eq!(pop_release(&mut q, &pool), "l1");
        assert_eq!(pop_release(&mut q, &pool), "h2");
        assert_eq!(pop_release(&mut q, &pool), "l2");
        assert_eq!(pop_release(&mut q, &pool), "h3");
        assert!(q.is_empty());
    }

    #[test]
    fn tenant_weights_skew_the_share() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 4));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.set_tenant_weight("big", 2.0);
        for i in 0..6 {
            q.push(job_for(&format!("b{i}"), 4, "big", Priority::Normal));
            q.push(job_for(&format!("s{i}"), 4, "sml", Priority::Normal));
        }
        let mut big = 0;
        for _ in 0..6 {
            if pop_release(&mut q, &pool).starts_with('b') {
                big += 1;
            }
        }
        // Weight 2 vs 1: roughly two "big" placements per "sml" one.
        assert_eq!(big, 4, "expected a 2:1 split in the first 6 placements");
    }

    #[test]
    fn reactivated_tenant_does_not_bank_idle_time() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 4));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        // "busy" consumes 12 vCPUs of share while "late" is idle.
        for i in 0..3 {
            q.push(job_for(&format!("busy{i}"), 4, "busy", Priority::Normal));
        }
        for _ in 0..3 {
            pop_release(&mut q, &pool);
        }
        // Now both tenants queue two flares each. If "late" had banked its
        // idle time it would place all of its flares first; the activation
        // snap gives it parity instead: late, busy, late, busy.
        q.push(job_for("busy3", 4, "busy", Priority::Normal));
        q.push(job_for("busy4", 4, "busy", Priority::Normal));
        q.push(job_for("late0", 4, "late", Priority::Normal));
        q.push(job_for("late1", 4, "late", Priority::Normal));
        let order: Vec<String> = (0..4).map(|_| pop_release(&mut q, &pool)).collect();
        assert_eq!(order, vec!["busy3", "late0", "busy4", "late1"]);
    }

    #[test]
    fn idle_queue_starts_a_fresh_fairness_epoch() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 4));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        // A veteran tenant runs up a large consumption history...
        for i in 0..3 {
            q.push(job_for(&format!("a{i}"), 4, "vet", Priority::Normal));
        }
        for _ in 0..3 {
            pop_release(&mut q, &pool);
        }
        assert!(q.is_empty());
        // ...then the queue drains fully. A newcomer submitting into the
        // idle queue must not bank that history as an advantage: both
        // lanes restart at parity and alternate.
        q.push(job_for("n0", 4, "new", Priority::Normal));
        q.push(job_for("n1", 4, "new", Priority::Normal));
        q.push(job_for("v3", 4, "vet", Priority::Normal));
        q.push(job_for("v4", 4, "vet", Priority::Normal));
        let order: Vec<String> = (0..4).map(|_| pop_release(&mut q, &pool)).collect();
        assert_eq!(order, vec!["n0", "v3", "n1", "v4"]);
    }

    #[test]
    fn priority_then_fifo_within_a_tenant() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 4));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.push(job_for("n1", 4, "t", Priority::Normal));
        q.push(job_for("lo", 4, "t", Priority::Low));
        q.push(job_for("n2", 4, "t", Priority::Normal));
        q.push(job_for("hi", 4, "t", Priority::High));
        assert_eq!(pop_release(&mut q, &pool), "hi");
        assert_eq!(pop_release(&mut q, &pool), "n1");
        assert_eq!(pop_release(&mut q, &pool), "n2");
        assert_eq!(pop_release(&mut q, &pool), "lo");
    }

    #[test]
    fn remove_pulls_a_queued_flare_out_of_its_lane() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 4));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.push(job_for("a1", 4, "a", Priority::Normal));
        q.push(job_for("a2", 4, "a", Priority::Normal));
        assert!(q.remove("ghost").is_none());
        let gone = q.remove("a1").unwrap();
        assert_eq!(gone.flare_id, "a1");
        assert_eq!(q.len(), 1);
        assert_eq!(q.depth_by_tenant(), vec![("a".to_string(), 1)]);
        assert_eq!(pop_release(&mut q, &pool), "a2");
        assert!(q.depth_by_tenant().is_empty());
    }

    #[test]
    fn waiting_area_is_outside_the_drr_lanes() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 4));
        // A backfill budget of 1: if the parked child counted as a
        // skipped flare, the second pop below would trip the starvation
        // guard and place nothing.
        let mut q = FlareQueue::new(1);
        let mut child = job_for("child", 4, "dag", Priority::Normal);
        child.after = vec!["parent".to_string()];
        q.park_waiting(child);
        assert_eq!(q.waiting_len(), 1);
        assert_eq!(q.len(), 1);
        assert!(!q.is_empty());
        assert_eq!(q.waiting_edges(), vec![("child".into(), vec!["parent".into()])]);
        // Other flares place freely, repeatedly, past the parked child —
        // it consumes no backfill passes and skews no deficits.
        q.push(job_for("o1", 4, "other", Priority::Normal));
        q.push(job_for("o2", 4, "other", Priority::Normal));
        assert_eq!(pop_release(&mut q, &pool), "o1");
        assert_eq!(pop_release(&mut q, &pool), "o2");
        // The child is invisible to placement until promoted...
        assert!(q.pop_placeable(&pool).is_none());
        // ...and promotion is an ordinary push into its lane.
        let promoted = q.take_waiting("child").unwrap();
        q.push(promoted);
        assert_eq!(q.waiting_len(), 0);
        assert_eq!(pop_release(&mut q, &pool), "child");
        // `remove` (cancellation) reaches parked children too.
        let mut c2 = job_for("c2", 4, "dag", Priority::Normal);
        c2.after = vec!["parent".to_string()];
        q.park_waiting(c2);
        assert_eq!(q.remove("c2").unwrap().flare_id, "c2");
        assert!(q.take_waiting("c2").is_none());
        // Expiry reaches the holding area: a deadline lapses the same
        // whether a flare waits on capacity or on a parent.
        let mut c3 = job_with_deadline("c3", 4, Some(0));
        c3.after = vec!["parent".to_string()];
        q.park_waiting(c3);
        let expired = q.take_expired(Instant::now() + Duration::from_millis(1));
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].flare_id, "c3");
        assert!(q.is_empty());
    }

    #[test]
    fn edf_breaks_fifo_ties_within_a_priority_class() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 4));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.push(job_with_deadline("none", 4, None));
        q.push(job_with_deadline("late", 4, Some(60_000)));
        q.push(job_with_deadline("soon", 4, Some(10_000)));
        // EDF within the class: soon < late < no-deadline. But priority
        // still dominates the deadline tie-break.
        q.push(job_for("hi", 4, "t", Priority::High));
        assert_eq!(pop_release(&mut q, &pool), "hi");
        assert_eq!(pop_release(&mut q, &pool), "soon");
        assert_eq!(pop_release(&mut q, &pool), "late");
        assert_eq!(pop_release(&mut q, &pool), "none");
    }

    #[test]
    fn high_priority_dominates_lane_shares_across_tenants() {
        // Shares tie, and the lane-order tie-break favors tenant "a" — but
        // tenant "b" holds the only high flare. The class-major scan
        // places it first; the old lane-major scan would have placed
        // "a-n" out of the better-ordered lane.
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 4));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.push(job_for("a-n", 4, "a", Priority::Normal));
        q.push(job_for("b-hi", 4, "b", Priority::High));
        assert_eq!(pop_release(&mut q, &pool), "b-hi");
        assert_eq!(pop_release(&mut q, &pool), "a-n");
    }

    #[test]
    fn requeue_preempted_goes_to_the_head_of_its_class() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 4));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.push(job_for("hi", 4, "t", Priority::High));
        q.push(job_for("n1", 4, "t", Priority::Normal));
        q.push(job_for("n2", 4, "t", Priority::Normal));
        // A preempted normal-priority victim outranks queued normals (it
        // was already running) but never the high class above it.
        let mut victim = job_for("victim", 4, "t", Priority::Normal);
        victim.preempt_count = 1;
        victim.passed_over = 7;
        q.requeue_preempted(victim);
        assert_eq!(pop_release(&mut q, &pool), "hi");
        let (v, p) = q.pop_placeable(&pool).unwrap();
        assert_eq!(v.flare_id, "victim");
        assert_eq!(v.passed_over, 0, "requeue resets the backfill pass count");
        pool.release(&p.packs);
        assert_eq!(pop_release(&mut q, &pool), "n1");
        assert_eq!(pop_release(&mut q, &pool), "n2");
    }

    #[test]
    fn take_expired_pulls_only_overdue_flares() {
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.push(job_with_deadline("overdue", 4, Some(0)));
        q.push(job_with_deadline("fine", 4, Some(60_000)));
        q.push(job_with_deadline("forever", 4, None));
        let expired = q.take_expired(Instant::now() + Duration::from_millis(1));
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].flare_id, "overdue");
        assert_eq!(q.len(), 2);
        assert!(q.take_expired(Instant::now()).is_empty());
    }

    #[test]
    fn settle_replaces_provisional_charge_with_measured_usage() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 4));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.push(job_for("z1", 4, "z", Priority::Normal));
        q.push(job_for("z2", 4, "z", Priority::Normal));
        q.push(job_for("b1", 4, "b", Priority::Normal));
        q.push(job_for("b2", 4, "b", Priority::Normal));
        assert_eq!(pop_release(&mut q, &pool), "b1"); // 0:0 tie → name
        let (z1, p) = q.pop_placeable(&pool).unwrap();
        assert_eq!(z1.flare_id, "z1");
        assert_eq!(z1.charged, 4.0);
        pool.release(&p.packs);
        // z1 was cancelled almost immediately: settle the provisional
        // 4-vCPU charge down to the measured 0.1 vCPU·s. Lane z now holds
        // the better share, so z2 goes before b2 — with placement-time
        // billing the lanes would tie at 4 and the name tie-break would
        // put b2 first, billing z for capacity it never used.
        q.settle(&z1.tenant, z1.charged, 0.1);
        assert_eq!(pop_release(&mut q, &pool), "z2");
        assert_eq!(pop_release(&mut q, &pool), "b2");
    }

    #[test]
    fn quota_blocks_placement_even_with_free_capacity() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 16));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.set_tenant_quota("t", Some(4));
        q.push(job_for("t1", 4, "t", Priority::Normal));
        q.push(job_for("t2", 4, "t", Priority::Normal));
        let (t1, _packs) = q.pop_placeable(&pool).unwrap();
        assert_eq!(t1.flare_id, "t1");
        // 12 vCPUs are free, but the tenant holds its full quota: t2 waits
        // with an observable reason.
        assert!(q.pop_placeable(&pool).is_none());
        assert_eq!(q.quota_blocked_ids(), vec!["t2"]);
        let policy = &q.tenant_policies()[0];
        assert_eq!((policy.placed_vcpus, policy.quota), (4, Some(4)));
        // Releasing t1's reservation frees the quota; t2 places.
        q.settle("t", t1.charged, 1.0);
        let (t2, _) = q.pop_placeable(&pool).unwrap();
        assert_eq!(t2.flare_id, "t2");
        assert!(!t2.quota_blocked, "marks are cleared on each scan");
    }

    #[test]
    fn backfill_does_not_bypass_quota() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 16));
        // A tight backfill budget: if quota skips counted as passes, the
        // second other-tenant pop below would trip the starvation guard.
        let mut q = FlareQueue::new(1);
        q.set_tenant_quota("t", Some(4));
        q.push(job_for("big", 4, "t", Priority::Normal));
        q.push(job_for("small", 2, "t", Priority::Normal));
        assert_eq!(q.pop_placeable(&pool).unwrap().0.flare_id, "big");
        // "small" would fit the cluster *and* is a textbook backfill
        // candidate — but 4 + 2 exceeds the quota, so it must wait too.
        assert!(q.pop_placeable(&pool).is_none());
        assert_eq!(q.quota_blocked_ids(), vec!["small"]);
        // Other tenants are unaffected, repeatedly: a quota skip is not a
        // backfill pass, so the pass budget of 1 never halts the scan.
        q.push(job_for("o1", 4, "other", Priority::Normal));
        q.push(job_for("o2", 4, "other", Priority::Normal));
        assert_eq!(pop_release(&mut q, &pool), "o1");
        assert_eq!(pop_release(&mut q, &pool), "o2");
        // A full rescan with nothing placeable re-marks the quota wait.
        assert!(q.pop_placeable(&pool).is_none());
        assert_eq!(q.quota_blocked_ids(), vec!["small"]);
    }

    #[test]
    fn quota_blocked_waits_leave_drr_deficits_unaffected() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(2, 8));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.set_tenant_quota("a", Some(4));
        // a1 takes tenant a to its quota and keeps running.
        q.push(job_for("a1", 4, "a", Priority::Normal));
        let (a1, _) = q.pop_placeable(&pool).unwrap();
        assert_eq!(a1.flare_id, "a1");
        // While a is quota-blocked, b places twice (consuming share 8).
        q.push(job_for("a2", 4, "a", Priority::Normal));
        q.push(job_for("b1", 4, "b", Priority::Normal));
        q.push(job_for("b2", 4, "b", Priority::Normal));
        assert_eq!(pop_release(&mut q, &pool), "b1");
        assert_eq!(pop_release(&mut q, &pool), "b2");
        // a1 releases; a's share is 4 vs b's 8, so a2 goes first — the
        // quota-blocked wait neither charged nor discounted a's deficit.
        q.settle("a", a1.charged, 4.0);
        q.push(job_for("b3", 4, "b", Priority::Normal));
        assert_eq!(pop_release(&mut q, &pool), "a2");
        assert_eq!(pop_release(&mut q, &pool), "b3");
    }

    #[test]
    fn quota_cleared_with_none_lifts_the_cap() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 16));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.set_tenant_quota("t", Some(2));
        q.push(job_for("t1", 4, "t", Priority::Normal));
        assert!(q.pop_placeable(&pool).is_none(), "4 > quota 2");
        q.set_tenant_quota("t", None);
        assert_eq!(q.pop_placeable(&pool).unwrap().0.flare_id, "t1");
        assert_eq!(q.policy("t"), Some((1.0, None)));
    }

    /// `needed_by_node` helper for the single-node victim tests.
    fn need(node: &str, n: usize) -> BTreeMap<String, usize> {
        BTreeMap::from([(node.to_string(), n)])
    }

    #[test]
    fn select_victims_prefers_lowest_priority_then_recency() {
        let cand = |id: &str, priority, vcpus, seq| PreemptCandidate {
            flare_id: id.to_string(),
            priority,
            vcpus,
            seq,
            node: "node-0".to_string(),
        };
        let cands = vec![
            cand("norm-new", Priority::Normal, 4, 9),
            cand("low-old", Priority::Low, 4, 1),
            cand("low-new", Priority::Low, 4, 5),
        ];
        // 4 vCPUs needed: the newest low-priority flare alone covers it.
        assert_eq!(select_victims(&cands, &need("node-0", 4)), vec!["low-new"]);
        // 8 needed: both lows go before any normal is touched.
        let mut v = select_victims(&cands, &need("node-0", 8));
        v.sort();
        assert_eq!(v, vec!["low-new", "low-old"]);
        // 12 needed: the normal flare is drafted too.
        assert_eq!(select_victims(&cands, &need("node-0", 12)).len(), 3);
        // 13 needed: cannot cover — preempt nobody.
        assert!(select_victims(&cands, &need("node-0", 13)).is_empty());
        assert!(select_victims(&cands, &need("node-0", 0)).is_empty());
        // Victims on another node cannot free capacity on this one.
        assert!(select_victims(&cands, &need("node-1", 4)).is_empty());
    }

    #[test]
    fn select_victims_trims_redundant_reclaims() {
        let cand = |id: &str, vcpus, seq| PreemptCandidate {
            flare_id: id.to_string(),
            priority: Priority::Low,
            vcpus,
            seq,
            node: "node-0".to_string(),
        };
        // Recency order drafts small-new (2 vCPUs) and then big (8) to
        // cover 6; the trim pass finds big alone suffices (10 − 2 = 8 ≥ 6)
        // and releases small-new — the minimal reclaim wins over recency.
        let cands = vec![cand("big", 8, 1), cand("small-new", 2, 9)];
        assert_eq!(select_victims(&cands, &need("node-0", 6)), vec!["big"]);
    }

    #[test]
    fn select_victims_frees_contiguous_capacity_on_one_node() {
        let cand = |id: &str, vcpus, seq, node: &str| PreemptCandidate {
            flare_id: id.to_string(),
            priority: Priority::Low,
            vcpus,
            seq,
            node: node.to_string(),
        };
        let cands = vec![
            cand("a1", 2, 1, "node-a"),
            cand("a2", 2, 2, "node-a"),
            cand("b1", 4, 3, "node-b"),
        ];
        // 4 vCPUs short on either node. Aggregate selection would pick
        // victims across nodes (2+2 beats 4 on reclaim ties) — useless,
        // since no single node would end up with 4 contiguous free vCPUs.
        // The node-aware plan reclaims exactly one node's cover; on a
        // (4 reclaimed, 1 victim) vs (4 reclaimed, 2 victims) tie the
        // fewer-victims plan wins.
        let needs =
            BTreeMap::from([("node-a".to_string(), 4), ("node-b".to_string(), 4)]);
        assert_eq!(select_victims(&cands, &needs), vec!["b1"]);
        // A node whose candidates cannot cover its shortfall is skipped in
        // favor of one that can.
        let needs =
            BTreeMap::from([("node-a".to_string(), 6), ("node-b".to_string(), 4)]);
        assert_eq!(select_victims(&cands, &needs), vec!["b1"]);
        // No node can cover: preempt nobody.
        let needs =
            BTreeMap::from([("node-a".to_string(), 6), ("node-b".to_string(), 6)]);
        assert!(select_victims(&cands, &needs).is_empty());
    }

    #[test]
    fn settle_accumulates_lifetime_billing() {
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        assert_eq!(q.usage_of("t"), None);
        assert_eq!(q.settle("t", 4.0, 2.5), 2.5);
        assert_eq!(q.settle("t", 4.0, 1.5), 4.0);
        assert_eq!(q.usage_of("t"), Some(4.0));
        // Recovery restores the absolute total, not a delta.
        q.seed_billed("t", 10.0);
        assert_eq!(q.usage_of("t"), Some(10.0));
        assert_eq!(q.settle("t", 1.0, 1.0), 11.0);
        let policy = &q.tenant_policies()[0];
        assert_eq!(policy.billed_vcpu_s, 11.0);
        assert!(matches!(
            policy.to_json().get("vcpu_seconds"),
            Some(Json::Num(v)) if *v == 11.0
        ));
    }

    #[test]
    fn infeasible_flare_is_marked_but_backfill_continues() {
        let reg = crate::platform::node::NodeRegistry::new();
        reg.register("node-a", Arc::new(InvokerPool::new(&ClusterSpec::uniform(1, 4))));
        reg.register("node-b", Arc::new(InvokerPool::new(&ClusterSpec::uniform(1, 8))));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        // Locality steers the filler onto node-b, leaving free = [4, 4].
        let mut filler = job("filler", 4);
        filler.prior_node = Some("node-b".to_string());
        q.push(filler);
        let (_, filler_p) = q.pop_placeable(&reg).unwrap();
        assert_eq!(filler_p.node, "node-b");
        // Aggregate free is 8 ≥ 6, but no single node can host 6: "wide"
        // is marked infeasible while "narrow" backfills past it.
        q.push(job("wide", 6));
        q.push(job("narrow", 4));
        let (narrow, _) = q.pop_placeable(&reg).expect("backfill places narrow");
        assert_eq!(narrow.flare_id, "narrow");
        assert_eq!(q.infeasible_ids(), vec!["wide"]);
        // The mark is re-derived each scan: once node-b frees up, the
        // flare places there and no mark remains.
        reg.release("node-b", &filler_p.packs);
        let (wide, wide_p) = q.pop_placeable(&reg).unwrap();
        assert_eq!((wide.flare_id.as_str(), wide_p.node.as_str()), ("wide", "node-b"));
        assert!(q.infeasible_ids().is_empty());
    }

    #[test]
    fn wait_timeout_returns_none_until_delivery() {
        let slot = Arc::new(ResultSlot::new());
        let h = FlareHandle { flare_id: "f".into(), slot: slot.clone() };
        assert!(h.wait_timeout(Duration::from_millis(10)).is_none());
        assert!(!h.is_finished());
        slot.deliver(Err(anyhow!("boom")));
        let r = h.wait_timeout(Duration::from_millis(10)).expect("delivered");
        assert!(r.is_err());
    }

    #[test]
    fn spillback_replans_after_losing_reserve_race() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(2, 4));
        // Attempt 0 plans 4 workers onto invoker 0 ([4,4] free), but a rival
        // reserves 2 vCPUs there inside the snapshot→reserve window; the
        // spillback re-plan sees [2,4] and lands across both invokers.
        let rival = PackSpec { invoker_id: 0, workers: vec![100, 101] };
        let packs = place_with_spillback_observed(
            &pool,
            PackingStrategy::Heterogeneous,
            4,
            SPILLBACK_RETRIES,
            |attempt| {
                if attempt == 0 {
                    pool.reserve(std::slice::from_ref(&rival)).unwrap();
                }
            },
        )
        .expect("spillback should re-plan and place");
        let mut invokers: Vec<usize> = packs.iter().map(|p| p.invoker_id).collect();
        invokers.sort_unstable();
        assert_eq!(invokers, vec![0, 1]);
        assert_eq!(pool.free_vcpus(), vec![0, 2]);
    }

    #[test]
    fn spillback_retry_budget_is_bounded() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 8));
        let mut attempts = 0;
        let got = place_with_spillback_observed(
            &pool,
            PackingStrategy::Heterogeneous,
            8,
            2,
            |attempt| {
                attempts = attempt + 1;
                if attempt == 0 {
                    // A rival takes 1 vCPU inside the race window.
                    pool.reserve(&[PackSpec { invoker_id: 0, workers: vec![0] }]).unwrap();
                }
            },
        );
        // Attempt 0 lost the race; the re-plan sees only 7 free for a
        // burst of 8, so the flare stays queued without consuming capacity.
        assert!(got.is_none());
        assert_eq!(attempts, 1);
        assert_eq!(pool.free_vcpus(), vec![7]);
    }

    #[test]
    fn spillback_gives_up_when_capacity_never_materializes() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 4));
        pool.reserve(&[PackSpec { invoker_id: 0, workers: vec![0, 1] }]).unwrap();
        // Needs 4, only 2 free: plan fails, stay queued.
        assert!(place_with_spillback(&pool, PackingStrategy::Heterogeneous, 4, 3).is_none());
        assert_eq!(pool.free_vcpus(), vec![2]);
    }
}
