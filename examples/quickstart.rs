//! Quickstart: define a burst, deploy it, flare it.
//!
//! A Monte-Carlo π estimator: every worker samples points, partial counts
//! are aggregated with the BCM `reduce` collective, and the root broadcasts
//! the final estimate — the smallest complete burst program (paper Table 2
//! API: deploy / flare / work / collectives).
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use burstc::bcm::BurstContext;
use burstc::platform::{register_work, BurstConfig, Controller, FlareOptions};
use burstc::util::json::Json;
use burstc::util::rng::Pcg;

fn work(params: &Json, ctx: &BurstContext) -> anyhow::Result<Json> {
    let samples = params.num_or("samples", 200_000.0) as u64;

    // Every worker samples its own stream (seeded by worker id).
    let mut rng = Pcg::new(0xCAFE + ctx.worker_id as u64);
    let mut hits = 0u64;
    for _ in 0..samples {
        let (x, y) = (rng.f64(), rng.f64());
        if x * x + y * y <= 1.0 {
            hits += 1;
        }
    }

    // Aggregate [hits, samples] across the burst with a locality-aware
    // reduce (co-located workers fold in memory; packs fold over the wire).
    let fold = |a: &mut Vec<u8>, b: &[u8]| {
        let (h1, s1) = decode(a);
        let (h2, s2) = decode(b);
        *a = encode(h1 + h2, s1 + s2);
    };
    let reduced = ctx.reduce(0, encode(hits, samples), &fold)?;

    // Root computes π and broadcasts it so every worker returns the answer.
    let pi_bytes = reduced.map(|r| {
        let (h, s) = decode(&r);
        (4.0 * h as f64 / s as f64).to_le_bytes().to_vec()
    });
    let got = ctx.broadcast(0, pi_bytes)?;
    let pi = f64::from_le_bytes(got[..8].try_into().unwrap());

    Ok(Json::obj(vec![
        ("worker", ctx.worker_id.into()),
        ("pack", ctx.pack_id().into()),
        ("pi", pi.into()),
    ]))
}

fn encode(hits: u64, samples: u64) -> Vec<u8> {
    let mut v = hits.to_le_bytes().to_vec();
    v.extend(samples.to_le_bytes());
    v
}

fn decode(b: &[u8]) -> (u64, u64) {
    (
        u64::from_le_bytes(b[..8].try_into().unwrap()),
        u64::from_le_bytes(b[8..16].try_into().unwrap()),
    )
}

fn main() -> anyhow::Result<()> {
    // 1. Register the work function (stands in for uploading a package).
    register_work("pi", Arc::new(work));

    // 2. A burst platform: 2 invokers x 8 vCPUs.
    let controller = Controller::test_platform(2, 8, 1.0);

    // 3. Deploy the burst definition.
    controller.deploy(
        "monte-carlo-pi",
        "pi",
        BurstConfig { granularity: 4, strategy: "homogeneous".into(), ..Default::default() },
    )?;

    // 4. Flare it: burst size = number of input params (paper §4.2).
    let burst_size = 8;
    let params = vec![Json::obj(vec![("samples", 200_000.into())]); burst_size];
    let result = controller.flare("monte-carlo-pi", params, &FlareOptions::default())?;

    // 5. Inspect.
    let pi = result.outputs[0].get("pi").unwrap().as_f64().unwrap();
    println!("π ≈ {pi:.4} from {burst_size} workers in {} packs", result.packs.len());
    println!(
        "invocation: {:.2}s (modeled) | work: {:.3}s (measured) | remote traffic: {} B",
        result.startup.all_ready_s,
        result.work_wall_s,
        result.traffic.remote()
    );
    assert!((pi - std::f64::consts::PI).abs() < 0.01);
    println!("quickstart OK");
    Ok(())
}
