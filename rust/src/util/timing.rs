//! Precise sleeping and stopwatch helpers.
//!
//! The simulated backends enforce modeled service times with plain
//! `thread::sleep`: on Linux hrtimers this is accurate to tens of
//! microseconds, and — crucially on the single-CPU boxes this runs on —
//! sleeping never steals cycles from the threads doing real work (a
//! spin-tail implementation serializes the whole simulation on 1 core).

use std::time::{Duration, Instant};

use crate::util::sync::{LockRank, MutexGuard, RankedMutex};

/// Sleep for `d` (no spinning; see module docs).
pub fn precise_sleep(d: Duration) {
    if d.is_zero() {
        return;
    }
    std::thread::sleep(d);
}

/// Duration from a float of seconds (panics on negative).
pub fn secs_f64(s: f64) -> Duration {
    Duration::from_secs_f64(s.max(0.0))
}

/// Serialization lock for wall-clock-sensitive tests: ratio assertions on a
/// single-CPU box are only meaningful when contention tests don't overlap.
/// Ranked lowest ([`LockRank::TimingTest`]) because a test holds it across
/// whole workloads that acquire everything else.
pub fn timing_test_lock() -> MutexGuard<'static, ()> {
    static LOCK: RankedMutex<()> = RankedMutex::new(LockRank::TimingTest, ());
    LOCK.lock_recover()
}

/// Simple stopwatch.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precise_sleep_is_precise() {
        for micros in [50u64, 500, 2000] {
            let d = Duration::from_micros(micros);
            let t = Instant::now();
            precise_sleep(d);
            let e = t.elapsed();
            assert!(e >= d, "slept {e:?} < {d:?}");
            // Allow generous upper slack on loaded single-CPU boxes.
            assert!(e < d + Duration::from_millis(30), "slept {e:?} for {d:?}");
        }
    }

    #[test]
    fn zero_sleep_returns() {
        precise_sleep(Duration::ZERO);
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        precise_sleep(Duration::from_micros(300));
        assert!(sw.secs() > 0.0);
    }
}
