//! Simulated in-memory KV server core (Redis / DragonflyDB).
//!
//! Real bytes flow through real shard maps and locks; the *structural*
//! properties that drive Fig. 8's shapes are modeled directly:
//!
//! * **Redis** is single-threaded: one shard whose executor lock serializes
//!   every operation's service time, so aggregate throughput flat-lines
//!   under parallel load.
//! * **DragonflyDB** shards the keyspace across executor threads, so it
//!   scales until the server NIC cap binds.
//! * The **stream** flavor pays a constant overhead multiplier per op
//!   (entry metadata + consumer-group bookkeeping), matching the paper's
//!   lists-beat-streams observation.
//!
//! Service time per op = `op_latency + bytes / shard_bw`, enforced with a
//! precise sleep *while holding the shard executor lock* (that is what
//! "single-threaded" means), then the payload is actually stored/served.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::super::backend::{BackendCounters, BackendStats, CancelWakers, RemoteBackend};
use super::super::mailbox::Bytes;
use crate::cluster::netmodel::NetParams;
use crate::cluster::tokenbucket::TokenBucket;
use crate::util::cancel::{CancelToken, Waker};
use crate::util::sync::{LockRank, RankedMutex};
use crate::util::timing::{precise_sleep, secs_f64};

#[derive(Default)]
struct ShardStore {
    queues: HashMap<String, VecDeque<Bytes>>,
    published: HashMap<String, Bytes>,
}

struct Shard {
    /// Executor: service time is paid under this lock (models the shard's
    /// single event-loop thread).
    executor: RankedMutex<()>,
    store: RankedMutex<ShardStore>,
    cv: Condvar,
}

/// Simulated sharded KV server.
pub struct KvServer {
    name: String,
    shards: Arc<Vec<Shard>>,
    op_latency_s: f64,
    per_byte_s: f64,
    time_scale: f64,
    /// Server NIC cap shared by all shards (bytes/sec of modeled time).
    nic: TokenBucket,
    counters: BackendCounters,
    /// One trip waker per cancel token: a trip pokes every shard condvar.
    wakers: CancelWakers,
}

impl KvServer {
    pub fn new(
        name: &str,
        shards: usize,
        op_latency_s: f64,
        shard_bw: f64,
        params: &NetParams,
    ) -> Arc<KvServer> {
        let scale = params.time_scale.max(1e-9);
        Arc::new(KvServer {
            name: name.to_string(),
            shards: Arc::new(
                (0..shards.max(1))
                    .map(|_| Shard {
                        executor: RankedMutex::new(LockRank::KvExecutor, ()),
                        store: RankedMutex::new(LockRank::BackendStore, ShardStore::default()),
                        cv: Condvar::new(),
                    })
                    .collect(),
            ),
            op_latency_s,
            per_byte_s: 1.0 / shard_bw,
            time_scale: params.time_scale,
            nic: TokenBucket::new(params.server_nic_bw / scale, params.server_nic_bw / 4.0),
            counters: BackendCounters::default(),
            wakers: CancelWakers::default(),
        })
    }

    /// Wire a cancel token's trip into every shard condvar (once per token).
    fn wire_cancel(&self, token: &CancelToken) {
        let shards = Arc::downgrade(&self.shards);
        self.wakers.ensure(token, || {
            Arc::new(move || {
                if let Some(shards) = shards.upgrade() {
                    for sh in shards.iter() {
                        // Briefly take the store lock before notifying so a
                        // waiter between its reason() check and its wait
                        // never misses the trip.
                        drop(sh.store.lock());
                        sh.cv.notify_all();
                    }
                }
            }) as Arc<Waker>
        });
    }

    /// Redis-like: single-threaded event loop.
    pub fn redis(params: &NetParams, stream: bool) -> Arc<KvServer> {
        let (lat, bw, name) = if stream {
            (
                params.redis_op_latency_s * params.stream_overhead,
                params.redis_core_bw / params.stream_overhead,
                "redis-stream",
            )
        } else {
            (params.redis_op_latency_s, params.redis_core_bw, "redis-list")
        };
        KvServer::new(name, 1, lat, bw, params)
    }

    /// DragonflyDB-like: shared-nothing shards on multiple threads.
    pub fn dragonfly(params: &NetParams, stream: bool) -> Arc<KvServer> {
        let (lat, bw, name) = if stream {
            (
                params.dragonfly_op_latency_s * params.stream_overhead,
                params.dragonfly_shard_bw / params.stream_overhead,
                "dragonfly-stream",
            )
        } else {
            (params.dragonfly_op_latency_s, params.dragonfly_shard_bw, "dragonfly-list")
        };
        KvServer::new(name, params.dragonfly_shards, lat, bw, params)
    }

    fn shard_of(&self, key: &str) -> &Shard {
        // FNV-1a over the key bytes.
        let mut h: u64 = 0xcbf29ce484222325;
        for b in key.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        &self.shards[(h % self.shards.len() as u64) as usize]
    }

    /// Pay an op's service time on the shard's executor thread.
    fn serve(&self, shard: &Shard, bytes: usize) {
        let _exec = shard.executor.lock();
        let t = self.op_latency_s + bytes as f64 * self.per_byte_s;
        precise_sleep(secs_f64(t * self.time_scale));
    }
}

impl RemoteBackend for KvServer {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn put(&self, key: &str, data: Bytes) -> Result<()> {
        let shard = self.shard_of(key);
        self.nic.take(data.len() as f64);
        self.serve(shard, data.len());
        self.counters.puts.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes_in.fetch_add(data.len() as u64, Ordering::Relaxed);
        let mut st = shard.store.lock();
        st.queues.entry(key.to_string()).or_default().push_back(data);
        shard.cv.notify_all();
        Ok(())
    }

    fn fetch(&self, key: &str, timeout: Duration) -> Result<Bytes> {
        self.fetch_cancellable(key, timeout, None)
    }

    fn fetch_cancellable(
        &self,
        key: &str,
        timeout: Duration,
        cancel: Option<&CancelToken>,
    ) -> Result<Bytes> {
        if let Some(token) = cancel {
            self.wire_cancel(token);
        }
        let shard = self.shard_of(key);
        let deadline = Instant::now() + timeout;
        let data = {
            let mut st = shard.store.lock();
            loop {
                if let Some(q) = st.queues.get_mut(key) {
                    if let Some(v) = q.pop_front() {
                        break v;
                    }
                }
                if let Some(reason) = cancel.and_then(CancelToken::reason) {
                    return Err(anyhow!(
                        "{}: fetch('{key}') aborted: flare {}",
                        self.name,
                        reason.name()
                    ));
                }
                let now = Instant::now();
                if now >= deadline {
                    return Err(anyhow!("{}: fetch('{key}') timed out", self.name));
                }
                let (g, _) = st.wait_timeout(&shard.cv, deadline - now);
                st = g;
            }
        };
        self.nic.take(data.len() as f64);
        self.serve(shard, data.len());
        self.counters.gets.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes_out.fetch_add(data.len() as u64, Ordering::Relaxed);
        Ok(data)
    }

    fn publish(&self, key: &str, data: Bytes) -> Result<()> {
        let shard = self.shard_of(key);
        self.nic.take(data.len() as f64);
        self.serve(shard, data.len());
        self.counters.puts.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes_in.fetch_add(data.len() as u64, Ordering::Relaxed);
        let mut st = shard.store.lock();
        st.published.insert(key.to_string(), data);
        shard.cv.notify_all();
        Ok(())
    }

    fn read(&self, key: &str, timeout: Duration) -> Result<Bytes> {
        self.read_cancellable(key, timeout, None)
    }

    fn read_cancellable(
        &self,
        key: &str,
        timeout: Duration,
        cancel: Option<&CancelToken>,
    ) -> Result<Bytes> {
        if let Some(token) = cancel {
            self.wire_cancel(token);
        }
        let shard = self.shard_of(key);
        let deadline = Instant::now() + timeout;
        let data = {
            let mut st = shard.store.lock();
            loop {
                if let Some(v) = st.published.get(key) {
                    break v.clone();
                }
                if let Some(reason) = cancel.and_then(CancelToken::reason) {
                    return Err(anyhow!(
                        "{}: read('{key}') aborted: flare {}",
                        self.name,
                        reason.name()
                    ));
                }
                let now = Instant::now();
                if now >= deadline {
                    return Err(anyhow!("{}: read('{key}') timed out", self.name));
                }
                let (g, _) = st.wait_timeout(&shard.cv, deadline - now);
                st = g;
            }
        };
        self.nic.take(data.len() as f64);
        self.serve(shard, data.len());
        self.counters.gets.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes_out.fetch_add(data.len() as u64, Ordering::Relaxed);
        Ok(data)
    }

    fn clear_prefix(&self, prefix: &str) {
        for shard in &self.shards {
            let mut st = shard.store.lock();
            st.queues.retain(|k, _| !k.starts_with(prefix));
            st.published.retain(|k, _| !k.starts_with(prefix));
        }
    }

    fn stats(&self) -> BackendStats {
        self.counters.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::timing::Stopwatch;

    fn fast() -> NetParams {
        NetParams::scaled(1e-6)
    }

    #[test]
    fn put_fetch_roundtrip() {
        let s = KvServer::redis(&fast(), false);
        s.put("k", vec![1, 2, 3].into()).unwrap();
        let v = s.fetch("k", Duration::from_millis(100)).unwrap();
        assert_eq!(v.as_slice(), &[1u8, 2, 3][..]);
        // Queue now empty: second fetch times out.
        assert!(s.fetch("k", Duration::from_millis(10)).is_err());
    }

    #[test]
    fn queue_fifo_order() {
        let s = KvServer::dragonfly(&fast(), false);
        s.put("q", vec![1].into()).unwrap();
        s.put("q", vec![2].into()).unwrap();
        assert_eq!(s.fetch("q", Duration::from_millis(10)).unwrap().as_slice(), &[1u8][..]);
        assert_eq!(s.fetch("q", Duration::from_millis(10)).unwrap().as_slice(), &[2u8][..]);
    }

    #[test]
    fn publish_read_many() {
        let s = KvServer::redis(&fast(), false);
        s.publish("bc", vec![9].into()).unwrap();
        for _ in 0..3 {
            assert_eq!(s.read("bc", Duration::from_millis(10)).unwrap().as_slice(), &[9u8][..]);
        }
    }

    #[test]
    fn fetch_blocks_for_producer() {
        let s = KvServer::dragonfly(&fast(), false);
        let s2 = s.clone();
        let h = std::thread::spawn(move || s2.fetch("late", Duration::from_secs(2)).unwrap());
        std::thread::sleep(Duration::from_millis(30));
        s.put("late", vec![5].into()).unwrap();
        assert_eq!(h.join().unwrap().as_slice(), &[5u8][..]);
    }

    #[test]
    fn clear_prefix_scoped() {
        let s = KvServer::redis(&fast(), false);
        s.put("f1/a", vec![1].into()).unwrap();
        s.put("f2/a", vec![2].into()).unwrap();
        s.clear_prefix("f1/");
        assert!(s.fetch("f1/a", Duration::from_millis(10)).is_err());
        assert!(s.fetch("f2/a", Duration::from_millis(10)).is_ok());
    }

    #[test]
    fn redis_serializes_dragonfly_scales() {
        // 16 concurrent 8 MiB puts at realistic service costs compressed
        // 2×: redis (1 executor) serializes them; dragonfly spreads them
        // over its shards and must be measurably faster.
        let _guard = crate::util::timing::timing_test_lock();
        let params = NetParams::scaled(0.5);
        let redis = KvServer::redis(&params, false);
        let fly = KvServer::dragonfly(&params, false);

        let run = |s: Arc<KvServer>| {
            let t = Stopwatch::start();
            std::thread::scope(|scope| {
                for i in 0..16 {
                    let s = &s;
                    scope.spawn(move || {
                        s.put(&format!("k{i}"), vec![0u8; 8 << 20].into()).unwrap()
                    });
                }
            });
            t.secs()
        };
        let tr = run(redis);
        let tf = run(fly);
        assert!(tr > tf * 1.6, "redis {tr} dragonfly {tf}");
    }

    #[test]
    fn stream_flavor_slower() {
        let _guard = crate::util::timing::timing_test_lock();
        let params = NetParams::scaled(1.0);
        let list = KvServer::redis(&params, false);
        let stream = KvServer::redis(&params, true);
        let payload = Bytes::from(vec![0u8; 64 << 20]);
        let t1 = Stopwatch::start();
        list.put("a", payload.clone()).unwrap();
        let tl = t1.secs();
        let t2 = Stopwatch::start();
        stream.put("b", payload).unwrap();
        let ts = t2.secs();
        assert!(ts > tl * 1.2, "list {tl} stream {ts}");
    }

    #[test]
    fn cancellable_fetch_unwinds_at_the_trip() {
        let s = KvServer::dragonfly(&fast(), false);
        let token = CancelToken::new();
        let s2 = s.clone();
        let t2 = token.clone();
        let h = std::thread::spawn(move || {
            s2.fetch_cancellable("never", Duration::from_secs(60), Some(&t2)).unwrap_err()
        });
        std::thread::sleep(Duration::from_millis(30));
        let trip = Instant::now();
        token.cancel();
        let err = h.join().unwrap();
        assert!(err.to_string().contains("cancelled"), "{err}");
        assert!(
            trip.elapsed() < Duration::from_millis(500),
            "unwind took {:?} after the trip",
            trip.elapsed()
        );
    }

    #[test]
    fn stats_counted() {
        let s = KvServer::redis(&fast(), false);
        s.put("k", vec![0u8; 10].into()).unwrap();
        s.fetch("k", Duration::from_millis(10)).unwrap();
        let st = s.stats();
        assert_eq!(st.puts, 1);
        assert_eq!(st.gets, 1);
        assert_eq!(st.bytes_in, 10);
        assert_eq!(st.bytes_out, 10);
    }
}
