//! Project lint: concurrency-correctness rules the compiler cannot enforce.
//!
//! `cargo run -p xtask -- lint` scans `rust/src` and fails (exit 1) on:
//!
//! * **raw-lock** — a raw `std::sync::Mutex`/`RwLock` outside
//!   `util/sync.rs`. Every lock must be a `RankedMutex`/`RankedRwLock`
//!   carrying a `LockRank`, or the deadlock tracker has a blind spot.
//! * **illegal-transition** — a direct `.status =` write outside
//!   `platform/db.rs`. Status moves must go through
//!   `FlareRecord::set_status` / `BurstDb::update_flare`, which enforce
//!   the one legal transition table (kept between the
//!   `lint: transition-table-begin/end` markers in db.rs — the lint also
//!   fails if those markers disappear).
//! * **wal-outside-lock** — `stage_entry`/`stage_item` referenced outside
//!   `platform/db.rs`, or declared `pub` inside it. WAL staging is only
//!   correct under the mutated shard's write lock, so it must stay private
//!   to the module that owns that invariant.
//! * **blocking-in-reactor** — a blocking call (`sleep`, `wait`, blocking
//!   reads/writes, `recv`, `join`) inside a `lint: reactor-begin/end`
//!   region. The HTTP reactor is a single event loop; one blocked
//!   iteration stalls every connection.
//!
//! Escape hatch: append `// lint: allow(<rule>)` to the offending line (or
//! the line above it) to acknowledge a deliberate exception. `#[cfg(test)]`
//! modules are skipped for raw-lock and illegal-transition — tests may
//! build gates and fixtures however they like.

use std::fmt;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => {}
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint");
            return ExitCode::from(2);
        }
    }
    // xtask lives at rust/xtask; the crate sources are at rust/src.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../src");
    let mut files = Vec::new();
    collect_rs(&root, &mut files);
    files.sort();
    let mut violations = Vec::new();
    for f in &files {
        let raw = match std::fs::read_to_string(f) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("xtask lint: cannot read {}: {e}", f.display());
                return ExitCode::from(2);
            }
        };
        let rel = f
            .strip_prefix(&root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        violations.extend(lint_file(&rel, &raw));
    }
    if violations.is_empty() {
        println!("xtask lint: {} files clean", files.len());
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            eprintln!("{v}");
        }
        eprintln!("xtask lint: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for e in entries.flatten() {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
}

#[derive(Debug)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Run every rule over one file. `rel` is the path relative to `src/`
/// (forward slashes).
pub fn lint_file(rel: &str, raw: &str) -> Vec<Violation> {
    let masked = mask(raw);
    let test_spans = test_mod_spans(&masked);
    let lines: Vec<&str> = raw.lines().collect();
    let mut out = Vec::new();
    rule_raw_lock(rel, raw, &masked, &test_spans, &lines, &mut out);
    rule_illegal_transition(rel, raw, &masked, &test_spans, &lines, &mut out);
    rule_wal_outside_lock(rel, raw, &masked, &test_spans, &lines, &mut out);
    rule_blocking_in_reactor(rel, raw, &masked, &lines, &mut out);
    out
}

// ---------------------------------------------------------------- masking

/// Blank out comment and string-literal contents (with spaces, preserving
/// newlines) so token scans cannot match inside them.
fn mask(src: &str) -> String {
    let b = src.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                while i < b.len() && b[i] != b'\n' {
                    out.push(b' ');
                    i += 1;
                }
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                let mut depth = 1;
                out.push(b' ');
                out.push(b' ');
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                        depth += 1;
                        out.push(b' ');
                        out.push(b' ');
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                        depth -= 1;
                        out.push(b' ');
                        out.push(b' ');
                        i += 2;
                    } else {
                        out.push(if b[i] == b'\n' { b'\n' } else { b' ' });
                        i += 1;
                    }
                }
            }
            b'"' => {
                out.push(b' ');
                i += 1;
                while i < b.len() {
                    match b[i] {
                        b'\\' if i + 1 < b.len() => {
                            out.push(b' ');
                            out.push(b' ');
                            i += 2;
                        }
                        b'"' => {
                            out.push(b' ');
                            i += 1;
                            break;
                        }
                        c => {
                            out.push(if c == b'\n' { b'\n' } else { b' ' });
                            i += 1;
                        }
                    }
                }
            }
            // Raw strings: r"..." / r#"..."# (any hash count).
            b'r' if i + 1 < b.len()
                && (b[i + 1] == b'"' || b[i + 1] == b'#')
                && !prev_is_ident(b, i) =>
            {
                let mut j = i + 1;
                let mut hashes = 0;
                while j < b.len() && b[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
                if j < b.len() && b[j] == b'"' {
                    for _ in i..=j {
                        out.push(b' ');
                    }
                    i = j + 1;
                    'raw: while i < b.len() {
                        if b[i] == b'"' {
                            let mut k = i + 1;
                            let mut h = 0;
                            while k < b.len() && b[k] == b'#' && h < hashes {
                                h += 1;
                                k += 1;
                            }
                            if h == hashes {
                                for _ in i..k {
                                    out.push(b' ');
                                }
                                i = k;
                                break 'raw;
                            }
                        }
                        out.push(if b[i] == b'\n' { b'\n' } else { b' ' });
                        i += 1;
                    }
                } else {
                    out.push(b[i]);
                    i += 1;
                }
            }
            // Char literal (vs lifetime): 'x' or '\x' with a closing quote.
            b'\'' if is_char_literal(b, i) => {
                out.push(b' ');
                i += 1;
                while i < b.len() {
                    match b[i] {
                        b'\\' if i + 1 < b.len() => {
                            out.push(b' ');
                            out.push(b' ');
                            i += 2;
                        }
                        b'\'' => {
                            out.push(b' ');
                            i += 1;
                            break;
                        }
                        c => {
                            out.push(if c == b'\n' { b'\n' } else { b' ' });
                            i += 1;
                        }
                    }
                }
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

fn prev_is_ident(b: &[u8], i: usize) -> bool {
    i > 0 && (b[i - 1].is_ascii_alphanumeric() || b[i - 1] == b'_')
}

fn is_char_literal(b: &[u8], i: usize) -> bool {
    // 'a' or '\n' (escape): closing quote 2 or 3 bytes on; `'a` (lifetime)
    // has none.
    if i + 2 < b.len() && b[i + 1] == b'\\' {
        return true; // escaped char literal
    }
    i + 2 < b.len() && b[i + 2] == b'\''
}

// ---------------------------------------------------------- test-mod spans

/// Byte ranges of `#[cfg(test)] mod ... { ... }` blocks (in masked text).
fn test_mod_spans(masked: &str) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut from = 0;
    while let Some(off) = masked[from..].find("#[cfg(test)]") {
        let attr = from + off;
        from = attr + 12;
        // Brace-match from the first `{` after the attribute (covers the
        // following `mod tests { ... }`, or a cfg(test)-gated item).
        let Some(open_rel) = masked[from..].find('{') else { break };
        let open = from + open_rel;
        let mut depth = 0usize;
        let mut end = masked.len();
        for (k, c) in masked[open..].char_indices() {
            match c {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    if depth == 0 {
                        end = open + k + 1;
                        break;
                    }
                }
                _ => {}
            }
        }
        spans.push((attr, end));
        from = end;
    }
    spans
}

fn in_spans(spans: &[(usize, usize)], pos: usize) -> bool {
    spans.iter().any(|&(a, b)| pos >= a && pos < b)
}

// ---------------------------------------------------------------- helpers

fn line_of(src: &str, pos: usize) -> usize {
    src[..pos].bytes().filter(|&b| b == b'\n').count() + 1
}

/// `// lint: allow(<rule>)` on the violation line or the one above it.
fn allowed(lines: &[&str], line: usize, rule: &str) -> bool {
    let tag = format!("lint: allow({rule})");
    let here = lines.get(line - 1).is_some_and(|l| l.contains(&tag));
    let above = line >= 2 && lines.get(line - 2).is_some_and(|l| l.contains(&tag));
    here || above
}

/// All occurrences of `token` in `masked` that stand on identifier
/// boundaries (no `[A-Za-z0-9_]` immediately before, nor after when the
/// token itself ends in an identifier character).
fn token_positions(masked: &str, token: &str) -> Vec<usize> {
    let mb = masked.as_bytes();
    let tb = token.as_bytes();
    // Boundary checks apply only where the token itself is identifier-like:
    // `.wait(` starts with `.` and is always preceded by an identifier.
    let starts_ident = tb.first().is_some_and(|&c| c.is_ascii_alphanumeric() || c == b'_');
    let ends_ident = tb.last().is_some_and(|&c| c.is_ascii_alphanumeric() || c == b'_');
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(off) = masked[from..].find(token) {
        let pos = from + off;
        from = pos + 1;
        if starts_ident && prev_is_ident(mb, pos) {
            continue;
        }
        if ends_ident {
            let after = pos + tb.len();
            if after < mb.len() && (mb[after].is_ascii_alphanumeric() || mb[after] == b'_') {
                continue;
            }
        }
        out.push(pos);
    }
    out
}

// ------------------------------------------------------------------ rules

const RAW_LOCK: &str = "raw-lock";
const ILLEGAL_TRANSITION: &str = "illegal-transition";
const WAL_OUTSIDE_LOCK: &str = "wal-outside-lock";
const BLOCKING_IN_REACTOR: &str = "blocking-in-reactor";

fn rule_raw_lock(
    rel: &str,
    raw: &str,
    masked: &str,
    test_spans: &[(usize, usize)],
    lines: &[&str],
    out: &mut Vec<Violation>,
) {
    if rel.ends_with("util/sync.rs") {
        return;
    }
    const TOKENS: &[&str] = &[
        "std::sync::Mutex",
        "std::sync::RwLock",
        "Mutex::new(",
        "RwLock::new(",
        "Mutex<",
        "RwLock<",
    ];
    let mut seen_lines = Vec::new();
    for token in TOKENS {
        for pos in token_positions(masked, token) {
            if in_spans(test_spans, pos) {
                continue;
            }
            let line = line_of(raw, pos);
            if seen_lines.contains(&line) || allowed(lines, line, RAW_LOCK) {
                continue;
            }
            seen_lines.push(line);
            out.push(Violation {
                file: rel.to_string(),
                line,
                rule: RAW_LOCK,
                msg: format!(
                    "raw `{token}` — use util::sync::RankedMutex/RankedRwLock with a LockRank"
                ),
            });
        }
    }
}

fn rule_illegal_transition(
    rel: &str,
    raw: &str,
    masked: &str,
    test_spans: &[(usize, usize)],
    lines: &[&str],
    out: &mut Vec<Violation>,
) {
    if rel.ends_with("platform/db.rs") {
        // The one module allowed to write `.status` raw — but only while
        // the legal-transition table is present and marked.
        for marker in ["lint: transition-table-begin", "lint: transition-table-end"] {
            if !raw.contains(marker) {
                out.push(Violation {
                    file: rel.to_string(),
                    line: 1,
                    rule: ILLEGAL_TRANSITION,
                    msg: format!("missing `{marker}` marker around can_transition"),
                });
            }
        }
        return;
    }
    for pos in token_positions(masked, ".status") {
        // `.status =` (assignment), not `.status ==` / `.status` reads.
        let rest = masked[pos + ".status".len()..].trim_start();
        if rest.starts_with('=') && !rest.starts_with("==") {
            let line = line_of(raw, pos);
            if in_spans(test_spans, pos) || allowed(lines, line, ILLEGAL_TRANSITION) {
                continue;
            }
            out.push(Violation {
                file: rel.to_string(),
                line,
                rule: ILLEGAL_TRANSITION,
                msg: "direct `.status =` write — use FlareRecord::set_status (checked \
                      against the transition table) via BurstDb::update_flare"
                    .to_string(),
            });
        }
    }
}

fn rule_wal_outside_lock(
    rel: &str,
    raw: &str,
    masked: &str,
    test_spans: &[(usize, usize)],
    lines: &[&str],
    out: &mut Vec<Violation>,
) {
    if rel.ends_with("platform/db.rs") {
        // Staging must stay private: a `pub` staging fn would let callers
        // enqueue WAL entries outside the shard-lock scope that orders them.
        for name in ["fn stage_entry", "fn stage_item"] {
            for pos in token_positions(masked, name) {
                let before = &masked[pos.saturating_sub(16)..pos];
                if before.contains("pub") {
                    let line = line_of(raw, pos);
                    if allowed(lines, line, WAL_OUTSIDE_LOCK) {
                        continue;
                    }
                    out.push(Violation {
                        file: rel.to_string(),
                        line,
                        rule: WAL_OUTSIDE_LOCK,
                        msg: format!(
                            "`{name}` must stay private — WAL staging is only ordered \
                             under the mutated shard's write lock"
                        ),
                    });
                }
            }
        }
        return;
    }
    for name in ["stage_entry(", "stage_item("] {
        for pos in token_positions(masked, name) {
            let line = line_of(raw, pos);
            if in_spans(test_spans, pos) || allowed(lines, line, WAL_OUTSIDE_LOCK) {
                continue;
            }
            out.push(Violation {
                file: rel.to_string(),
                line,
                rule: WAL_OUTSIDE_LOCK,
                msg: format!(
                    "`{name}..)` outside platform/db.rs — WAL staging must happen \
                     inside db.rs under the shard write lock"
                ),
            });
        }
    }
}

fn rule_blocking_in_reactor(
    rel: &str,
    raw: &str,
    masked: &str,
    lines: &[&str],
    out: &mut Vec<Violation>,
) {
    // Region markers live in comments, so they are read from the raw lines.
    let mut regions: Vec<(usize, usize)> = Vec::new(); // 1-based line ranges
    let mut open: Option<usize> = None;
    for (i, l) in lines.iter().enumerate() {
        if l.contains("lint: reactor-begin") {
            if open.is_some() {
                out.push(Violation {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: BLOCKING_IN_REACTOR,
                    msg: "nested `lint: reactor-begin` (previous region unclosed)".into(),
                });
            }
            open = Some(i + 1);
        } else if l.contains("lint: reactor-end") {
            match open.take() {
                Some(b) => regions.push((b, i + 1)),
                None => out.push(Violation {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: BLOCKING_IN_REACTOR,
                    msg: "`lint: reactor-end` without a matching begin".into(),
                }),
            }
        }
    }
    if let Some(b) = open {
        out.push(Violation {
            file: rel.to_string(),
            line: b,
            rule: BLOCKING_IN_REACTOR,
            msg: "`lint: reactor-begin` never closed".into(),
        });
    }
    if regions.is_empty() {
        return;
    }
    const BLOCKING: &[&str] = &[
        "thread::sleep",
        "precise_sleep(",
        "read_to_end",
        "read_exact",
        "write_all",
        ".wait(",
        ".wait_timeout(",
        ".recv()",
        ".join()",
    ];
    for token in BLOCKING {
        for pos in token_positions(masked, token) {
            let line = line_of(raw, pos);
            if !regions.iter().any(|&(b, e)| line > b && line < e) {
                continue;
            }
            if allowed(lines, line, BLOCKING_IN_REACTOR) {
                continue;
            }
            out.push(Violation {
                file: rel.to_string(),
                line,
                rule: BLOCKING_IN_REACTOR,
                msg: format!(
                    "blocking call `{token}..` inside a reactor region — the event \
                     loop must never block"
                ),
            });
        }
    }
}

// ------------------------------------------------------------------ tests

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(found: &[Violation]) -> Vec<&'static str> {
        found.iter().map(|v| v.rule).collect()
    }

    #[test]
    fn raw_lock_fires_on_seeded_violation() {
        let src = "fn f() { let m = std::sync::Mutex::new(()); let _ = m; }\n";
        let v = lint_file("platform/foo.rs", src);
        assert!(rules(&v).contains(&RAW_LOCK), "{v:?}");
    }

    #[test]
    fn raw_lock_ignores_ranked_wrappers_and_sync_rs() {
        let ok = "fn f() { let m = RankedMutex::new(LockRank::Leaf, ()); let _ = m; }\n";
        assert!(lint_file("platform/foo.rs", ok).is_empty());
        let raw = "fn f() { let m = std::sync::Mutex::new(()); let _ = m; }\n";
        assert!(lint_file("util/sync.rs", raw).is_empty());
    }

    #[test]
    fn raw_lock_skips_test_mods_comments_and_allows() {
        let in_test = "#[cfg(test)]\nmod tests {\n    fn g() { let _ = Mutex::new(0); }\n}\n";
        assert!(lint_file("platform/foo.rs", in_test).is_empty());
        let in_comment = "// a Mutex::new( in prose\nfn f() {}\n";
        assert!(lint_file("platform/foo.rs", in_comment).is_empty());
        let escaped = "static G: std::sync::Mutex<u8> = std::sync::Mutex::new(0); // lint: allow(raw-lock)\n";
        assert!(lint_file("platform/foo.rs", escaped).is_empty());
    }

    #[test]
    fn illegal_transition_fires_outside_db() {
        let src = "fn f(r: &mut FlareRecord) { r.status = FlareStatus::Completed; }\n";
        let v = lint_file("platform/controller.rs", src);
        assert!(rules(&v).contains(&ILLEGAL_TRANSITION), "{v:?}");
        // Reads and comparisons are fine.
        let ok = "fn f(r: &FlareRecord) -> bool { r.status == FlareStatus::Queued }\n";
        assert!(lint_file("platform/controller.rs", ok).is_empty());
    }

    #[test]
    fn illegal_transition_requires_db_markers() {
        let no_markers = "fn can_transition() {}\n";
        let v = lint_file("platform/db.rs", no_markers);
        assert_eq!(rules(&v), vec![ILLEGAL_TRANSITION, ILLEGAL_TRANSITION]);
        let with = "// lint: transition-table-begin\nfn can_transition() {}\n// lint: transition-table-end\nfn f(r: &mut FlareRecord) { r.status = FlareStatus::Queued; }\n";
        assert!(lint_file("platform/db.rs", with).is_empty());
    }

    #[test]
    fn wal_staging_fires_outside_db_and_on_pub_decl() {
        let outside = "fn f(db: &BurstDb) { db.stage_entry(Json::Null); }\n";
        let v = lint_file("platform/controller.rs", outside);
        assert!(rules(&v).contains(&WAL_OUTSIDE_LOCK), "{v:?}");
        let pub_decl = "// lint: transition-table-begin\n// lint: transition-table-end\nimpl BurstDb { pub fn stage_entry(&self) {} }\n";
        let v = lint_file("platform/db.rs", pub_decl);
        assert!(rules(&v).contains(&WAL_OUTSIDE_LOCK), "{v:?}");
        let private = "// lint: transition-table-begin\n// lint: transition-table-end\nimpl BurstDb { fn stage_entry(&self) {} }\n";
        assert!(lint_file("platform/db.rs", private).is_empty());
    }

    #[test]
    fn blocking_in_reactor_fires_inside_region_only() {
        let bad = "// lint: reactor-begin\nfn f() { std::thread::sleep(D); }\n// lint: reactor-end\n";
        let v = lint_file("platform/http.rs", bad);
        assert!(rules(&v).contains(&BLOCKING_IN_REACTOR), "{v:?}");
        let outside = "fn f() { std::thread::sleep(D); }\n";
        assert!(lint_file("platform/http.rs", outside).is_empty());
        let escaped = "// lint: reactor-begin\nfn f() { std::thread::sleep(D); // lint: allow(blocking-in-reactor)\n}\n// lint: reactor-end\n";
        assert!(lint_file("platform/http.rs", escaped).is_empty());
    }

    #[test]
    fn unbalanced_reactor_markers_are_violations() {
        let unclosed = "// lint: reactor-begin\nfn f() {}\n";
        assert!(rules(&lint_file("a.rs", unclosed)).contains(&BLOCKING_IN_REACTOR));
        let stray_end = "fn f() {}\n// lint: reactor-end\n";
        assert!(rules(&lint_file("a.rs", stray_end)).contains(&BLOCKING_IN_REACTOR));
    }

    #[test]
    fn masking_handles_strings_and_nested_comments() {
        let src = "let s = \"Mutex::new(\"; /* outer /* Mutex::new( */ still comment */ let c = 'x';\n";
        let m = mask(src);
        assert!(!m.contains("Mutex::new("), "{m}");
        assert_eq!(m.len(), src.len());
        assert!(lint_file("platform/foo.rs", src).is_empty());
    }
}
