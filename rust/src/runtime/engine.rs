//! PJRT execution engine: loads the AOT HLO-text artifacts (see
//! `python/compile/aot.py`), compiles them once on the PJRT CPU client, and
//! serves execute requests from worker threads.
//!
//! The `xla` crate's client handles are `Rc`-based (not `Send`), so each
//! engine is a dedicated OS thread owning its own client + executables;
//! workers talk to it over channels. `EnginePool` shards requests across
//! several engines.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

use anyhow::{anyhow, Context, Result};

use super::tensor::Tensor;
use crate::util::json::Json;
use crate::util::sync::{LockRank, RankedMutex};

/// Parsed artifact manifest (written by `make artifacts`).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub units: HashMap<String, UnitSpec>,
}

#[derive(Debug, Clone)]
pub struct UnitSpec {
    pub file: String,
    pub inputs: Vec<(Vec<usize>, String)>,
    pub outputs: Vec<(Vec<usize>, String)>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text)?;
        if j.str_or("format", "") != "hlo-text" {
            return Err(anyhow!("unsupported artifact format"));
        }
        let mut units = HashMap::new();
        let units_j = j.get("units").and_then(Json::as_obj).ok_or_else(|| anyhow!("no units"))?;
        for (name, u) in units_j {
            let spec = |key: &str| -> Result<Vec<(Vec<usize>, String)>> {
                u.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("unit {name}: missing {key}"))?
                    .iter()
                    .map(|io| {
                        let shape = io
                            .get("shape")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| anyhow!("missing shape"))?
                            .iter()
                            .map(|d| d.as_usize().unwrap_or(0))
                            .collect();
                        let dtype = io.str_or("dtype", "float32").to_string();
                        Ok((shape, dtype))
                    })
                    .collect()
            };
            units.insert(
                name.clone(),
                UnitSpec {
                    file: u.str_or("file", "").to_string(),
                    inputs: spec("inputs")?,
                    outputs: spec("outputs")?,
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), units })
    }

    pub fn unit(&self, name: &str) -> Result<&UnitSpec> {
        self.units
            .get(name)
            .ok_or_else(|| anyhow!("unknown AOT unit '{name}' (have: {:?})", {
                let mut k: Vec<&String> = self.units.keys().collect();
                k.sort();
                k
            }))
    }
}

enum Request {
    Execute { unit: String, inputs: Vec<Tensor>, reply: mpsc::Sender<Result<Vec<Tensor>>> },
    Shutdown,
}

/// One PJRT engine thread.
pub struct Engine {
    tx: mpsc::Sender<Request>,
    handle: Option<std::thread::JoinHandle<()>>,
    pub manifest: Manifest,
}

impl Engine {
    pub fn start(artifact_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let (tx, rx) = mpsc::channel::<Request>();
        let m2 = manifest.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || engine_main(m2, rx, ready_tx))
            .expect("spawn engine thread");
        ready_rx.recv().map_err(|_| anyhow!("engine thread died during init"))??;
        Ok(Engine { tx, handle: Some(handle), manifest })
    }

    /// Execute one AOT unit. Blocks until the engine thread replies.
    pub fn execute(&self, unit: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request::Execute { unit: unit.to_string(), inputs, reply: reply_tx })
            .map_err(|_| anyhow!("engine thread gone"))?;
        reply_rx.recv().map_err(|_| anyhow!("engine thread dropped reply"))?
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn engine_main(manifest: Manifest, rx: mpsc::Receiver<Request>, ready: mpsc::Sender<Result<()>>) {
    // Build client + compile all units; report init status.
    let init = (|| -> Result<(xla::PjRtClient, HashMap<String, xla::PjRtLoadedExecutable>)> {
        let client = xla::PjRtClient::cpu()?;
        let mut exes = HashMap::new();
        for (name, unit) in &manifest.units {
            let path = manifest.dir.join(&unit.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("loading {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e}"))?;
            exes.insert(name.clone(), exe);
        }
        Ok((client, exes))
    })();
    let (_client, exes) = match init {
        Ok(v) => {
            let _ = ready.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Execute { unit, inputs, reply } => {
                let res = run_unit(&manifest, &exes, &unit, inputs);
                let _ = reply.send(res);
            }
        }
    }
}

fn run_unit(
    manifest: &Manifest,
    exes: &HashMap<String, xla::PjRtLoadedExecutable>,
    unit: &str,
    inputs: Vec<Tensor>,
) -> Result<Vec<Tensor>> {
    let spec = manifest.unit(unit)?;
    let exe = exes.get(unit).ok_or_else(|| anyhow!("unit '{unit}' not compiled"))?;
    if inputs.len() != spec.inputs.len() {
        return Err(anyhow!(
            "unit '{unit}': expected {} inputs, got {}",
            spec.inputs.len(),
            inputs.len()
        ));
    }
    let mut literals = Vec::with_capacity(inputs.len());
    for (i, (t, (shape, dtype))) in inputs.iter().zip(&spec.inputs).enumerate() {
        if t.shape() != shape.as_slice() || t.dtype() != dtype {
            return Err(anyhow!(
                "unit '{unit}' input {i}: expected {dtype}{shape:?}, got {}{:?}",
                t.dtype(),
                t.shape()
            ));
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = match t {
            Tensor::F32(d, _) => xla::Literal::vec1(d).reshape(&dims)?,
            Tensor::I32(d, _) => xla::Literal::vec1(d).reshape(&dims)?,
        };
        literals.push(lit);
    }
    let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
    // AOT lowers with return_tuple=True: always a tuple.
    let parts = result.to_tuple()?;
    if parts.len() != spec.outputs.len() {
        return Err(anyhow!(
            "unit '{unit}': expected {} outputs, got {}",
            spec.outputs.len(),
            parts.len()
        ));
    }
    parts
        .into_iter()
        .zip(&spec.outputs)
        .map(|(lit, (shape, dtype))| {
            let out = match dtype.as_str() {
                "float32" => Tensor::F32(lit.to_vec::<f32>()?, shape.clone()),
                "int32" => Tensor::I32(lit.to_vec::<i32>()?, shape.clone()),
                other => return Err(anyhow!("unsupported output dtype {other}")),
            };
            Ok(out)
        })
        .collect()
}

/// Round-robin pool of engines (each its own thread + compiled copies).
pub struct EnginePool {
    engines: Vec<Engine>,
    next: AtomicUsize,
}

impl EnginePool {
    pub fn start(artifact_dir: &Path, n: usize) -> Result<EnginePool> {
        let engines: Result<Vec<Engine>> =
            (0..n.max(1)).map(|_| Engine::start(artifact_dir)).collect();
        Ok(EnginePool { engines: engines?, next: AtomicUsize::new(0) })
    }

    pub fn execute(&self, unit: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.engines.len();
        self.engines[i].execute(unit, inputs)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.engines[0].manifest
    }
}

static GLOBAL_POOL: RankedMutex<Option<std::sync::Arc<EnginePool>>> =
    RankedMutex::new(LockRank::Leaf, None);

/// The process-wide engine pool, created on first use from
/// `$BURSTC_ARTIFACTS` (default `./artifacts`), with `$BURSTC_ENGINES`
/// engine threads (default 1 — this image has a single CPU).
pub fn global_pool() -> Result<std::sync::Arc<EnginePool>> {
    let mut g = GLOBAL_POOL.lock();
    if let Some(p) = g.as_ref() {
        return Ok(p.clone());
    }
    let dir = std::env::var("BURSTC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let n: usize =
        std::env::var("BURSTC_ENGINES").ok().and_then(|v| v.parse().ok()).unwrap_or(1);
    let pool = std::sync::Arc::new(EnginePool::start(Path::new(&dir), n)?);
    *g = Some(pool.clone());
    Ok(pool)
}
