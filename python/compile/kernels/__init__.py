"""L1 Pallas kernels for burstc worker compute.

Each kernel is the compute hot-spot of one burst application from the paper's
evaluation (Section 5.4):

- ``pagerank``  — blocked rank-contribution SpMV (dense blocks) used by the
  PageRank burst worker each iteration.
- ``sgd``       — fused logistic-regression gradient step used by the
  hyperparameter-tuning (grid search) burst workers.
- ``histogram`` — key-partition histogram used by TeraSort map workers to
  split records into range buckets ahead of the all-to-all shuffle.
- ``kmeans``    — assignment + accumulation step for the k-means burst
  (extension application mentioned in the paper's intro).

All kernels run with ``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU efficiency is estimated in DESIGN.md §Perf
from the BlockSpec tiling (VMEM footprint + MXU alignment).
"""
