//! Flare scheduling pipeline (paper Fig. 4 as a job-level scheduler):
//! **submit → admit → queue → place → execute → complete**.
//!
//! The controller admits flares into a *multi-tenant* queue (`FlareQueue`)
//! instead of packing inline. A dedicated scheduler thread drains the queue
//! with a two-level pick:
//!
//! 1. **Across tenants** — weighted deficit round-robin: each tenant lane
//!    accumulates the vCPUs placed on its behalf, and the lane with the
//!    lowest weighted share goes first, so a heavy tenant flooding the
//!    queue cannot starve a light one (the paper's group-invocation
//!    primitive only pays off if one burst cannot monopolize the cluster).
//! 2. **Within a tenant** — priority classes (`high`/`normal`/`low`), FIFO
//!    within a class.
//!
//! *Backfill* lets a small flare jump a head-of-line flare it cannot
//! unblock, bounded by an anti-starvation pass budget that halts the whole
//! scan once any flare has been passed too often — running flares drain,
//! capacity frees, and the blocked flare goes first.
//!
//! Placement races (a reservation lost between the load snapshot and
//! `InvokerPool::reserve`, cf. SPEAR's two-level scheduling spillback) are
//! retried against a fresh load view up to [`SPILLBACK_RETRIES`] times
//! before the flare simply stays queued.
//!
//! Every queued flare carries a shared [`CancelToken`]; the controller's
//! kill path (`Controller::cancel_flare`) removes queued flares directly
//! and trips the token of running ones, which the execution path observes
//! cooperatively at phase boundaries.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::controller::{Controller, FlareResult};
use super::db::WorkFn;
use super::invoker::InvokerPool;
use super::packing::{plan, PackSpec, PackingStrategy};
use crate::bcm::BackendKind;
use crate::util::cancel::CancelToken;
use crate::util::json::Json;
use crate::util::timing::Stopwatch;

/// How often a blocked flare may be passed by backfilled smaller flares
/// before the queue stops scheduling past it.
pub const MAX_BACKFILL_PASSES: u32 = 16;

/// Re-plan budget when `InvokerPool::reserve` loses a placement race.
pub const SPILLBACK_RETRIES: usize = 3;

/// Tenant lane used when a flare names none.
pub const DEFAULT_TENANT: &str = "default";

/// Scheduling priority class within a tenant lane. Higher classes are
/// placed first; FIFO within a class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

impl Priority {
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "low" => Some(Priority::Low),
            "normal" => Some(Priority::Normal),
            "high" => Some(Priority::High),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }
}

/// A flare admitted to the queue: the fully resolved execution spec.
pub struct QueuedFlare {
    pub flare_id: String,
    pub def_name: String,
    pub work: WorkFn,
    pub params: Vec<Json>,
    /// One worker (= one vCPU) per input param.
    pub burst_size: usize,
    pub strategy: PackingStrategy,
    pub backend: BackendKind,
    pub chunk_size: usize,
    pub faas: bool,
    /// Fair-share lane this flare is accounted to.
    pub tenant: String,
    /// Placement order within the tenant lane.
    pub priority: Priority,
    /// Shared kill switch: tripped by `Controller::cancel_flare`, observed
    /// cooperatively by the execution path.
    pub cancel: CancelToken,
    pub(crate) slot: Arc<ResultSlot>,
    /// Started at submit; read at placement to measure queue wait.
    pub submitted: Stopwatch,
    /// Times a later flare was backfilled past this one while it was blocked.
    pub passed_over: u32,
}

/// One-shot result mailbox shared by the execution thread and the waiter.
pub(crate) struct ResultSlot {
    result: Mutex<Option<Result<FlareResult>>>,
    cv: Condvar,
}

impl ResultSlot {
    pub(crate) fn new() -> ResultSlot {
        ResultSlot { result: Mutex::new(None), cv: Condvar::new() }
    }

    pub(crate) fn deliver(&self, r: Result<FlareResult>) {
        *self.result.lock().unwrap() = Some(r);
        self.cv.notify_all();
    }

    fn wait_take(&self) -> Result<FlareResult> {
        let mut guard = self.result.lock().unwrap();
        loop {
            if let Some(r) = guard.take() {
                return r;
            }
            guard = self.cv.wait(guard).unwrap();
        }
    }

    fn is_done(&self) -> bool {
        self.result.lock().unwrap().is_some()
    }
}

/// Handle to an in-flight flare returned by `Controller::submit_flare`.
/// Live status is in `BurstDb` (`Controller::flare_status`); the handle
/// carries the final `FlareResult` to the submitter.
pub struct FlareHandle {
    pub flare_id: String,
    pub(crate) slot: Arc<ResultSlot>,
}

impl FlareHandle {
    /// Block until the flare completes (or fails) and take its result.
    pub fn wait(self) -> Result<FlareResult> {
        self.slot.wait_take()
    }

    /// Non-blocking: has the flare reached a terminal state?
    pub fn is_finished(&self) -> bool {
        self.slot.is_done()
    }
}

/// Plan + reserve with bounded spillback: each attempt plans against a fresh
/// snapshot of the pool's free capacity, so losing a reservation race to a
/// concurrent placement triggers a re-plan instead of a failure. Returns
/// `None` when the flare does not fit the current load (stay queued) or the
/// retry budget is exhausted.
///
/// Today the single scheduler thread is the only `reserve` caller (others
/// only `release`, which cannot defeat a planned reservation), so the retry
/// branch is dormant by construction; it becomes live the moment placement
/// gains a second actor — SPEAR-style per-node schedulers, a second
/// controller, or direct `reserve` users — which is the two-level design
/// this module is built toward.
pub fn place_with_spillback(
    pool: &InvokerPool,
    strategy: PackingStrategy,
    burst_size: usize,
    retries: usize,
) -> Option<Vec<PackSpec>> {
    place_with_spillback_observed(pool, strategy, burst_size, retries, |_| {})
}

/// Test seam: `between_plan_and_reserve(i)` runs after attempt `i` planned
/// against its load snapshot but before it reserves — exactly the window a
/// concurrent placement can race into.
fn place_with_spillback_observed(
    pool: &InvokerPool,
    strategy: PackingStrategy,
    burst_size: usize,
    retries: usize,
    mut between_plan_and_reserve: impl FnMut(usize),
) -> Option<Vec<PackSpec>> {
    for attempt in 0..=retries {
        let free = pool.free_vcpus();
        let packs = plan(strategy, burst_size, &free).ok()?;
        between_plan_and_reserve(attempt);
        if pool.reserve(&packs).is_ok() {
            return Some(packs);
        }
        // Reservation lost to a concurrent placement; loop re-plans
        // against the fresh load view.
    }
    None
}

/// One tenant's lane: its pending flares (priority-then-FIFO order is the
/// insertion order) plus its deficit accounting.
struct TenantLane {
    name: String,
    jobs: VecDeque<QueuedFlare>,
    /// vCPUs placed on behalf of this tenant so far (the queued vCPU·time
    /// proxy the deficit round-robin ranks lanes by).
    consumed: f64,
    /// Fair-share weight; a lane with weight 2 is entitled to twice the
    /// placed vCPUs of a weight-1 lane.
    weight: f64,
}

impl TenantLane {
    fn new(name: &str) -> TenantLane {
        TenantLane {
            name: name.to_string(),
            jobs: VecDeque::new(),
            consumed: 0.0,
            weight: 1.0,
        }
    }

    /// Weighted share: lanes with the lowest share are scheduled first.
    fn share(&self) -> f64 {
        self.consumed / self.weight
    }
}

/// Multi-tenant capacity-aware queue: weighted deficit round-robin across
/// tenant lanes, priority-then-FIFO within a lane, bounded backfill with a
/// global anti-starvation guard.
pub struct FlareQueue {
    tenants: Vec<TenantLane>,
    max_backfill_passes: u32,
}

impl FlareQueue {
    pub fn new(max_backfill_passes: u32) -> FlareQueue {
        FlareQueue { tenants: Vec::new(), max_backfill_passes }
    }

    /// Set a tenant's fair-share weight (creating its lane if needed).
    pub fn set_tenant_weight(&mut self, tenant: &str, weight: f64) {
        let li = self.lane_index(tenant);
        self.tenants[li].weight = weight.max(f64::MIN_POSITIVE);
    }

    /// Lowest weighted share among lanes that currently hold jobs.
    fn min_active_share(&self) -> f64 {
        self.tenants
            .iter()
            .filter(|t| !t.jobs.is_empty())
            .map(TenantLane::share)
            .fold(f64::INFINITY, f64::min)
    }

    fn lane_index(&mut self, tenant: &str) -> usize {
        match self.tenants.iter().position(|t| t.name == tenant) {
            Some(i) => i,
            None => {
                self.tenants.push(TenantLane::new(tenant));
                self.tenants.len() - 1
            }
        }
    }

    pub fn push(&mut self, job: QueuedFlare) {
        // A lane (re)entering service snaps its consumption forward to the
        // current fair frontier: idle time is not banked, so neither a
        // brand-new tenant nor one returning from a quiet spell gets an
        // unbounded run of placements before everyone else is served again.
        let frontier = self.min_active_share();
        if frontier.is_infinite() {
            // The queue fully drained: start a fresh fairness epoch. Without
            // this, a veteran lane's historical consumption would let any
            // newcomer starve it for an unbounded catch-up run (the inverse
            // of the banked-idle-time problem the snap below solves).
            for t in &mut self.tenants {
                t.consumed = 0.0;
            }
        }
        let li = self.lane_index(&job.tenant);
        let lane = &mut self.tenants[li];
        if lane.jobs.is_empty() && frontier.is_finite() {
            lane.consumed = lane.consumed.max(frontier * lane.weight);
        }
        // Priority-then-FIFO: insert before the first strictly lower
        // priority, after every equal-or-higher one.
        let at = lane
            .jobs
            .iter()
            .position(|q| q.priority < job.priority)
            .unwrap_or(lane.jobs.len());
        lane.jobs.insert(at, job);
    }

    pub fn len(&self) -> usize {
        self.tenants.iter().map(|t| t.jobs.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.iter().all(|t| t.jobs.is_empty())
    }

    /// Queue depth per tenant, lanes with pending flares only, sorted by
    /// tenant name (the `/metrics` view).
    pub fn depth_by_tenant(&self) -> Vec<(String, usize)> {
        let mut v: Vec<(String, usize)> = self
            .tenants
            .iter()
            .filter(|t| !t.jobs.is_empty())
            .map(|t| (t.name.clone(), t.jobs.len()))
            .collect();
        v.sort();
        v
    }

    /// Remove a queued flare by id (the cancel-while-queued kill path).
    pub fn remove(&mut self, flare_id: &str) -> Option<QueuedFlare> {
        for lane in &mut self.tenants {
            if let Some(i) = lane.jobs.iter().position(|j| j.flare_id == flare_id) {
                return lane.jobs.remove(i);
            }
        }
        None
    }

    pub(crate) fn drain(&mut self) -> Vec<QueuedFlare> {
        self.tenants.iter_mut().flat_map(|t| t.jobs.drain(..)).collect()
    }

    /// Remove and return the first flare that can be placed right now,
    /// together with its reserved pack plan.
    ///
    /// Two-level pick: tenant lanes are scanned in ascending weighted-share
    /// order (deficit round-robin — ties broken by name for determinism);
    /// within a lane, jobs are scanned priority-then-FIFO. A flare that
    /// does not fit is skipped (backfill) unless it has already been passed
    /// `max_backfill_passes` times, in which case the whole scan stops and
    /// nothing may start — running flares drain, capacity frees, and the
    /// blocked flare goes first. A successful placement charges the lane's
    /// deficit with the flare's vCPU demand.
    pub fn pop_placeable(
        &mut self,
        pool: &InvokerPool,
    ) -> Option<(QueuedFlare, Vec<PackSpec>)> {
        let mut lane_order: Vec<usize> = (0..self.tenants.len())
            .filter(|&l| !self.tenants[l].jobs.is_empty())
            .collect();
        lane_order.sort_by(|&a, &b| {
            self.tenants[a]
                .share()
                .total_cmp(&self.tenants[b].share())
                .then_with(|| self.tenants[a].name.cmp(&self.tenants[b].name))
        });

        // Cheap necessary condition checked before running the packing
        // planner per job: a burst larger than the total free capacity can
        // never be placed, and on a saturated cluster that is every job —
        // this keeps the periodic rescan O(queue) comparisons, not
        // O(queue) plan() calls, under the queue lock. (Skipping a job this
        // way is exactly a failed placement: pass accounting is identical.)
        let total_free: usize = pool.free_vcpus().iter().sum();

        let mut chosen: Option<(usize, usize, Vec<PackSpec>)> = None;
        let mut skipped: Vec<(usize, usize)> = Vec::new();
        'scan: for &l in &lane_order {
            for (j, job) in self.tenants[l].jobs.iter().enumerate() {
                let placed = if job.burst_size <= total_free {
                    place_with_spillback(pool, job.strategy, job.burst_size, SPILLBACK_RETRIES)
                } else {
                    None
                };
                if let Some(packs) = placed {
                    chosen = Some((l, j, packs));
                    break 'scan;
                }
                if job.passed_over >= self.max_backfill_passes {
                    break 'scan; // starvation guard: stop the whole scan
                }
                skipped.push((l, j));
            }
        }
        let (l, j, packs) = chosen?;
        for &(sl, sj) in &skipped {
            self.tenants[sl].jobs[sj].passed_over += 1;
        }
        let job = self.tenants[l].jobs.remove(j).expect("index in range");
        self.tenants[l].consumed += job.burst_size as f64;
        Some((job, packs))
    }
}

/// State shared between the controller, the scheduler thread, and the
/// per-flare execution threads.
pub(crate) struct SchedState {
    pub(crate) queue: Mutex<FlareQueue>,
    cv: Condvar,
    /// Set by `wake` so a notification between scheduling passes is never
    /// lost (the scheduler re-checks before sleeping).
    dirty: AtomicBool,
    shutdown: AtomicBool,
}

impl SchedState {
    pub(crate) fn new(max_backfill_passes: u32) -> Arc<SchedState> {
        Arc::new(SchedState {
            queue: Mutex::new(FlareQueue::new(max_backfill_passes)),
            cv: Condvar::new(),
            dirty: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
        })
    }

    /// Nudge the scheduler: a flare was submitted or capacity was freed.
    pub(crate) fn wake(&self) {
        self.dirty.store(true, Ordering::Release);
        self.cv.notify_all();
    }

    pub(crate) fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        self.cv.notify_all();
    }
}

/// The scheduler thread body: drain placeable flares, sleep until woken.
/// Holds only a `Weak` controller so dropping the last external `Arc`
/// (which triggers `Controller::drop` → `SchedState::shutdown`) ends it.
pub(crate) fn scheduler_loop(state: Arc<SchedState>, controller: Weak<Controller>) {
    // Fail whatever never got placed so waiters don't hang forever — on
    // clean shutdown *and* if the scheduler thread itself panics.
    struct DrainOnExit(Arc<SchedState>);
    impl Drop for DrainOnExit {
        fn drop(&mut self) {
            // On the panic path the queue mutex may be poisoned (the panic
            // can originate under the lock); recover the inner state — a
            // second panic here would abort the process.
            let leftovers = self
                .0
                .queue
                .lock()
                .unwrap_or_else(|poisoned| poisoned.into_inner())
                .drain();
            for job in leftovers {
                job.slot.deliver(Err(anyhow!(
                    "scheduler stopped before flare '{}' was placed",
                    job.flare_id
                )));
            }
        }
    }
    let _drain = DrainOnExit(state.clone());

    while !state.shutdown.load(Ordering::Acquire) {
        if let Some(c) = controller.upgrade() {
            loop {
                let placed = state.queue.lock().unwrap().pop_placeable(&c.pool);
                match placed {
                    Some((job, packs)) => {
                        Controller::spawn_execution(&c, job, packs, &state)
                    }
                    None => break,
                }
            }
        }
        let guard = state.queue.lock().unwrap();
        if state.shutdown.load(Ordering::Acquire) {
            break;
        }
        if !state.dirty.swap(false, Ordering::AcqRel) {
            // Timeout bounds the window of any missed wake-up.
            let _ = state
                .cv
                .wait_timeout(guard, Duration::from_millis(25))
                .unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;

    fn job(id: &str, size: usize) -> QueuedFlare {
        job_for(id, size, DEFAULT_TENANT, Priority::Normal)
    }

    fn job_for(id: &str, size: usize, tenant: &str, priority: Priority) -> QueuedFlare {
        QueuedFlare {
            flare_id: id.to_string(),
            def_name: "d".into(),
            work: Arc::new(|_p, _ctx| Ok(Json::Null)),
            params: vec![Json::Null; size],
            burst_size: size,
            strategy: PackingStrategy::Heterogeneous,
            backend: BackendKind::DragonflyList,
            chunk_size: 1024,
            faas: false,
            tenant: tenant.to_string(),
            priority,
            cancel: CancelToken::new(),
            slot: Arc::new(ResultSlot::new()),
            submitted: Stopwatch::start(),
            passed_over: 0,
        }
    }

    /// Pop, assert the id, and release the reservation (serial-capacity
    /// helper for the fairness tests).
    fn pop_release(q: &mut FlareQueue, pool: &InvokerPool) -> String {
        let (job, packs) = q.pop_placeable(pool).expect("placeable");
        pool.release(&packs);
        job.flare_id
    }

    #[test]
    fn fifo_order_when_everything_fits() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 16));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.push(job("a", 4));
        q.push(job("b", 4));
        let (first, packs) = q.pop_placeable(&pool).unwrap();
        assert_eq!(first.flare_id, "a");
        assert_eq!(packs.iter().map(PackSpec::vcpus).sum::<usize>(), 4);
        let (second, _) = q.pop_placeable(&pool).unwrap();
        assert_eq!(second.flare_id, "b");
        assert!(q.pop_placeable(&pool).is_none());
        assert_eq!(pool.free_vcpus(), vec![8]);
    }

    #[test]
    fn backfill_lets_small_flare_pass_blocked_large_one() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 8));
        // 6 of 8 vCPUs already in use.
        pool.reserve(&[PackSpec { invoker_id: 0, workers: (0..6).collect() }]).unwrap();
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.push(job("big", 8)); // blocked: needs the whole machine
        q.push(job("small", 2));
        let (picked, _) = q.pop_placeable(&pool).unwrap();
        assert_eq!(picked.flare_id, "small");
        // The blocked head stays, with its pass recorded.
        assert_eq!(q.len(), 1);
        assert!(q.pop_placeable(&pool).is_none());
    }

    #[test]
    fn starvation_guard_stops_backfill_past_exhausted_flare() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 8));
        pool.reserve(&[PackSpec { invoker_id: 0, workers: (0..6).collect() }]).unwrap();
        let mut q = FlareQueue::new(2);
        q.push(job("big", 8));
        q.push(job("s1", 2));
        q.push(job("s2", 2));
        q.push(job("s3", 2));
        // Two backfills allowed...
        assert_eq!(q.pop_placeable(&pool).unwrap().0.flare_id, "s1");
        pool.release(&[PackSpec { invoker_id: 0, workers: vec![0, 1] }]);
        assert_eq!(q.pop_placeable(&pool).unwrap().0.flare_id, "s2");
        pool.release(&[PackSpec { invoker_id: 0, workers: vec![0, 1] }]);
        // ...then the guard trips: s3 would fit, but "big" has priority now.
        assert!(q.pop_placeable(&pool).is_none());
        // Once the rest of the machine frees, the big flare goes first.
        pool.release(&[PackSpec { invoker_id: 0, workers: (0..6).collect() }]);
        let (big, big_packs) = q.pop_placeable(&pool).unwrap();
        assert_eq!(big.flare_id, "big");
        pool.release(&big_packs);
        assert_eq!(q.pop_placeable(&pool).unwrap().0.flare_id, "s3");
    }

    #[test]
    fn tenants_alternate_under_equal_demand() {
        // Serial capacity (every flare needs the whole machine): a flooding
        // tenant and a light tenant must interleave, not FIFO.
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 4));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.push(job_for("h1", 4, "heavy", Priority::Normal));
        q.push(job_for("h2", 4, "heavy", Priority::Normal));
        q.push(job_for("h3", 4, "heavy", Priority::Normal));
        q.push(job_for("l1", 4, "light", Priority::Normal));
        q.push(job_for("l2", 4, "light", Priority::Normal));
        // Shares start equal; ties break by name ("heavy" < "light"), then
        // the deficit alternates the lanes.
        assert_eq!(pop_release(&mut q, &pool), "h1");
        assert_eq!(pop_release(&mut q, &pool), "l1");
        assert_eq!(pop_release(&mut q, &pool), "h2");
        assert_eq!(pop_release(&mut q, &pool), "l2");
        assert_eq!(pop_release(&mut q, &pool), "h3");
        assert!(q.is_empty());
    }

    #[test]
    fn tenant_weights_skew_the_share() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 4));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.set_tenant_weight("big", 2.0);
        for i in 0..6 {
            q.push(job_for(&format!("b{i}"), 4, "big", Priority::Normal));
            q.push(job_for(&format!("s{i}"), 4, "sml", Priority::Normal));
        }
        let mut big = 0;
        for _ in 0..6 {
            if pop_release(&mut q, &pool).starts_with('b') {
                big += 1;
            }
        }
        // Weight 2 vs 1: roughly two "big" placements per "sml" one.
        assert_eq!(big, 4, "expected a 2:1 split in the first 6 placements");
    }

    #[test]
    fn reactivated_tenant_does_not_bank_idle_time() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 4));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        // "busy" consumes 12 vCPUs of share while "late" is idle.
        for i in 0..3 {
            q.push(job_for(&format!("busy{i}"), 4, "busy", Priority::Normal));
        }
        for _ in 0..3 {
            pop_release(&mut q, &pool);
        }
        // Now both tenants queue two flares each. If "late" had banked its
        // idle time it would place all of its flares first; the activation
        // snap gives it parity instead: late, busy, late, busy.
        q.push(job_for("busy3", 4, "busy", Priority::Normal));
        q.push(job_for("busy4", 4, "busy", Priority::Normal));
        q.push(job_for("late0", 4, "late", Priority::Normal));
        q.push(job_for("late1", 4, "late", Priority::Normal));
        let order: Vec<String> = (0..4).map(|_| pop_release(&mut q, &pool)).collect();
        assert_eq!(order, vec!["busy3", "late0", "busy4", "late1"]);
    }

    #[test]
    fn idle_queue_starts_a_fresh_fairness_epoch() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 4));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        // A veteran tenant runs up a large consumption history...
        for i in 0..3 {
            q.push(job_for(&format!("a{i}"), 4, "vet", Priority::Normal));
        }
        for _ in 0..3 {
            pop_release(&mut q, &pool);
        }
        assert!(q.is_empty());
        // ...then the queue drains fully. A newcomer submitting into the
        // idle queue must not bank that history as an advantage: both
        // lanes restart at parity and alternate.
        q.push(job_for("n0", 4, "new", Priority::Normal));
        q.push(job_for("n1", 4, "new", Priority::Normal));
        q.push(job_for("v3", 4, "vet", Priority::Normal));
        q.push(job_for("v4", 4, "vet", Priority::Normal));
        let order: Vec<String> = (0..4).map(|_| pop_release(&mut q, &pool)).collect();
        assert_eq!(order, vec!["n0", "v3", "n1", "v4"]);
    }

    #[test]
    fn priority_then_fifo_within_a_tenant() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 4));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.push(job_for("n1", 4, "t", Priority::Normal));
        q.push(job_for("lo", 4, "t", Priority::Low));
        q.push(job_for("n2", 4, "t", Priority::Normal));
        q.push(job_for("hi", 4, "t", Priority::High));
        assert_eq!(pop_release(&mut q, &pool), "hi");
        assert_eq!(pop_release(&mut q, &pool), "n1");
        assert_eq!(pop_release(&mut q, &pool), "n2");
        assert_eq!(pop_release(&mut q, &pool), "lo");
    }

    #[test]
    fn remove_pulls_a_queued_flare_out_of_its_lane() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 4));
        let mut q = FlareQueue::new(MAX_BACKFILL_PASSES);
        q.push(job_for("a1", 4, "a", Priority::Normal));
        q.push(job_for("a2", 4, "a", Priority::Normal));
        assert!(q.remove("ghost").is_none());
        let gone = q.remove("a1").unwrap();
        assert_eq!(gone.flare_id, "a1");
        assert_eq!(q.len(), 1);
        assert_eq!(q.depth_by_tenant(), vec![("a".to_string(), 1)]);
        assert_eq!(pop_release(&mut q, &pool), "a2");
        assert!(q.depth_by_tenant().is_empty());
    }

    #[test]
    fn spillback_replans_after_losing_reserve_race() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(2, 4));
        // Attempt 0 plans 4 workers onto invoker 0 ([4,4] free), but a rival
        // reserves 2 vCPUs there inside the snapshot→reserve window; the
        // spillback re-plan sees [2,4] and lands across both invokers.
        let rival = PackSpec { invoker_id: 0, workers: vec![100, 101] };
        let packs = place_with_spillback_observed(
            &pool,
            PackingStrategy::Heterogeneous,
            4,
            SPILLBACK_RETRIES,
            |attempt| {
                if attempt == 0 {
                    pool.reserve(std::slice::from_ref(&rival)).unwrap();
                }
            },
        )
        .expect("spillback should re-plan and place");
        let mut invokers: Vec<usize> = packs.iter().map(|p| p.invoker_id).collect();
        invokers.sort_unstable();
        assert_eq!(invokers, vec![0, 1]);
        assert_eq!(pool.free_vcpus(), vec![0, 2]);
    }

    #[test]
    fn spillback_retry_budget_is_bounded() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 8));
        let mut attempts = 0;
        let got = place_with_spillback_observed(
            &pool,
            PackingStrategy::Heterogeneous,
            8,
            2,
            |attempt| {
                attempts = attempt + 1;
                if attempt == 0 {
                    // A rival takes 1 vCPU inside the race window.
                    pool.reserve(&[PackSpec { invoker_id: 0, workers: vec![0] }]).unwrap();
                }
            },
        );
        // Attempt 0 lost the race; the re-plan sees only 7 free for a
        // burst of 8, so the flare stays queued without consuming capacity.
        assert!(got.is_none());
        assert_eq!(attempts, 1);
        assert_eq!(pool.free_vcpus(), vec![7]);
    }

    #[test]
    fn spillback_gives_up_when_capacity_never_materializes() {
        let pool = InvokerPool::new(&ClusterSpec::uniform(1, 4));
        pool.reserve(&[PackSpec { invoker_id: 0, workers: vec![0, 1] }]).unwrap();
        // Needs 4, only 2 free: plan fails, stay queued.
        assert!(place_with_spillback(&pool, PackingStrategy::Heterogeneous, 4, 3).is_none());
        assert_eq!(pool.free_vcpus(), vec![2]);
    }
}
